"""Paper Fig. 4 analogue: total weights touched per 'epoch' — dense vs
fixed selection vs dynamic selection (coverage over time), plus the
per-iteration updated fraction (paper: 2% of conv weights)."""
from __future__ import annotations

import time

import jax

from repro.configs import SparseUpdateConfig, get_smoke_config
from repro.core import build_plan, coverage_after, selected_fraction


def run() -> list[tuple]:
    cfg = get_smoke_config("llama3-8b")
    sp_common = dict(update_ratio=0.2, num_update_layers=2, channel_block=8)
    fixed = SparseUpdateConfig(phase_fixed_early=10**6, phase_dynamic=0,
                               **sp_common)
    dynamic = SparseUpdateConfig(phase_fixed_early=10, phase_dynamic=40,
                                 phase_fixed_late=10, **sp_common)
    plan = build_plan(cfg, dynamic)
    t0 = time.perf_counter()
    frac_iter = selected_fraction(plan, cfg)
    rows = [("fig4/per_iteration_fraction", 0.0, f"{frac_iter:.4f}")]
    for steps in (10, 30, 60):
        c_fixed = coverage_after(plan, fixed, steps, None)
        c_dyn = coverage_after(plan, dynamic, steps, None)
        rows.append((f"fig4/coverage@{steps}", 0.0,
                     f"fixed={c_fixed:.3f};dynamic={c_dyn:.3f}"))
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig4/walltime", dt, "ok"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
