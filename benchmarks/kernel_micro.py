"""Kernel microbenchmarks: jnp oracle vs Pallas(interpret) wall time on CPU
(correctness-path timing only — TPU timing requires hardware), the
compute-skip ratio the block-sparse dW kernel achieves by construction, the
fused single-launch kernels vs the PR 1 per-shard / per-(K, shard)
loop-of-launches baselines (wall time AND static launch-site counts), and a
dense-scatter vs compact-gradient train-step comparison (step time and
compiler-reported peak temp memory).

Besides the CSV rows, `run()` fills the module-level RECORDS list with
machine-readable dicts (op, variant, shape, ratio, us, launches); kernel
records additionally carry roofline context from `benchmarks.roofline`
(flops, bytes, arith_intensity, bound) so each BENCH_kernels.json row shows
which side of the TPU ridge point the op sits on next to its launch count.
`benchmarks.run` dumps them to BENCH_kernels.json so the perf trajectory is
tracked across PRs."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.batched_dw import (batched_dw_kernel,
                                      batched_dw_pipelined_kernel)
from repro.kernels.masked_dw import (block_sparse_dw_kernel,
                                     block_sparse_dw_pipelined_kernel)
from repro.kernels.scatter_blocks import block_scatter_update_kernel
from repro.launch.hlo_analysis import kernel_launch_count

from benchmarks.roofline import kernel_roofline

RECORDS: list[dict] = []      # machine-readable output (BENCH_kernels.json)
BENCH_JSON = "BENCH_kernels.json"


def _time(fn, *args, n=5):
    """Mean wall time per call in µs; one untimed warmup call first."""
    jax.block_until_ready(fn(*args))          # warmup: compile + first run
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def _launches(fn, *args) -> int:
    return kernel_launch_count(jax.make_jaxpr(fn)(*args))


def run() -> list[tuple]:
    RECORDS.clear()
    rows = []
    m, k, n, block = 512, 256, 512, 64
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(np.random.default_rng(1).normal(size=(m, n)), jnp.float32)
    for ratio in (0.125, 0.25, 0.5, 1.0):
        n_sel = max(1, int(n // block * ratio))
        idx = jnp.arange(n_sel, dtype=jnp.int32)[None]      # [1 shard, n_sel]
        jr = jax.jit(lambda x, dy, idx: ref.block_sparse_dw_ref(x, dy, idx, block))
        t_ref = _time(jr, x, dy, idx)
        flops_skip = 1.0 - n_sel / (n // block)
        rows.append((f"kernel/masked_dw_r{ratio}", t_ref,
                     f"jnp_oracle;compute_skipped={flops_skip:.0%}"))
        sel = n_sel * block
        RECORDS.append({"op": "masked_dw", "variant": "jnp_oracle",
                        "shape": f"m{m}k{k}n{n}b{block}", "ratio": ratio,
                        "us": t_ref, "launches": 0,
                        **kernel_roofline(2.0 * m * k * sel,
                                          4.0 * (m * k + m * sel + k * sel))})
    # dense dW for comparison
    jd = jax.jit(lambda x, dy: jnp.einsum("mk,mn->kn", x, dy))
    rows.append(("kernel/dense_dw", _time(jd, x, dy), "baseline"))
    rows += fusion_comparison()
    rows += batched_dw_comparison()
    rows += train_step_comparison()
    return rows


def batched_dw_comparison() -> list[tuple]:
    """MoE expert-batched compact dW: the single-launch `batched_dw` kernel
    (grid spans experts x shards x selected blocks) vs the per-expert
    loop-of-launches it replaces, plus the double-buffered `emit_pipeline`
    variants of both dW kernels. Same eager-dispatch timing discipline as
    `fusion_comparison` (each un-jitted pallas_call pays a full dispatch —
    the cost the batching removes); launch-site counts are exact on any
    backend."""
    rows = []
    rng = np.random.default_rng(5)
    e, m, k, s, nb, blk = 4, 64, 64, 2, 8, 16
    n_sel = 2                                   # ratio 0.25
    n = s * nb * blk
    x = jnp.asarray(rng.normal(size=(e, m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(e, m, n)), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.choice(nb, n_sel, replace=False) for _ in range(s)]),
        jnp.int32)

    def dw_batched(x, dy, idx):
        return batched_dw_kernel(x, dy, idx, block=blk, tm=m, tk=k,
                                 interpret=True)

    def dw_batched_pipelined(x, dy, idx):
        return batched_dw_pipelined_kernel(x, dy, idx, block=blk, tm=32,
                                           tk=k, interpret=True)

    def dw_per_expert_loop(x, dy, idx):         # pre-PR: one launch/expert
        outs = [block_sparse_dw_kernel(x[ei], dy[ei], idx, block=blk,
                                       tm=m, tk=k, interpret=True)
                for ei in range(e)]
        return jnp.stack(outs)

    shape = f"e{e}m{m}k{k}s{s}nb{nb}b{blk}"
    sel = s * n_sel * blk
    rl = kernel_roofline(2.0 * e * m * k * sel,
                         4.0 * e * (m * k + m * sel + k * sel))
    for variant, fn in (("fused", dw_batched),
                        ("pipelined", dw_batched_pipelined),
                        ("per_expert_loop", dw_per_expert_loop)):
        us = _time(fn, x, dy, idx, n=3)          # eager: dispatch per launch
        launches = _launches(fn, x, dy, idx)
        rows.append((f"kernel/batched_dw_{variant}", us,
                     f"launches={launches};eager_dispatch"))
        RECORDS.append({"op": "batched_dw", "variant": variant,
                        "shape": shape, "ratio": n_sel / nb, "us": us,
                        "launches": launches, "timing": "eager_dispatch",
                        **rl})

    def dw_pipelined(x2, dy2, idx):
        return block_sparse_dw_pipelined_kernel(x2, dy2, idx, block=blk,
                                                tm=32, tk=k, interpret=True)

    us = _time(dw_pipelined, x[0], dy[0], idx, n=3)
    launches = _launches(dw_pipelined, x[0], dy[0], idx)
    rows.append(("kernel/dw_pipelined", us,
                 f"launches={launches};eager_dispatch"))
    RECORDS.append({"op": "masked_dw", "variant": "pipelined",
                    "shape": f"m{m}k{k}s{s}nb{nb}b{blk}",
                    "ratio": n_sel / nb, "us": us, "launches": launches,
                    "timing": "eager_dispatch",
                    **kernel_roofline(2.0 * m * k * sel,
                                      4.0 * (m * k + m * sel + k * sel))})
    return rows


def fusion_comparison() -> list[tuple]:
    """Fused single-launch kernels vs the PR 1 loop-of-launches baselines.

    dW and writeback are timed EAGERLY: each un-jitted pallas_call pays a
    full dispatch — the CPU-interpret analogue of kernel-launch overhead,
    which is exactly the cost the fusion removes (under jit, interpret mode
    carries every output buffer through its grid loop, an emulation
    artifact that anti-correlates with launch count). The fused optimizer
    is timed jitted vs the jitted jnp gather->rule->scatter path it
    replaces. Launch-site counts are backend-independent."""
    rows = []
    rng = np.random.default_rng(2)
    m, k, s, nb, blk = 128, 64, 4, 8, 16
    n_sel = 2                                   # ratio 0.25
    n = s * nb * blk
    loc = nb * blk
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.choice(nb, n_sel, replace=False) for _ in range(s)]),
        jnp.int32)

    def dw_fused(x, dy, idx):
        return block_sparse_dw_kernel(x, dy, idx, block=blk, tm=m, tk=k,
                                      interpret=True)

    def dw_loop(x, dy, idx):                    # PR 1: one launch per shard
        outs = [block_sparse_dw_kernel(x, dy[:, si * loc:(si + 1) * loc],
                                       idx[si:si + 1], block=blk, tm=m, tk=k,
                                       interpret=True)
                for si in range(s)]
        return jnp.concatenate(outs, axis=1)

    shape = f"m{m}k{k}s{s}nb{nb}b{blk}"
    sel = s * n_sel * blk
    rl = kernel_roofline(2.0 * m * k * sel,
                         4.0 * (m * k + m * sel + k * sel))
    for variant, fn in (("fused", dw_fused), ("per_shard_loop", dw_loop)):
        us = _time(fn, x, dy, idx, n=3)          # eager: dispatch per launch
        launches = _launches(fn, x, dy, idx)
        rows.append((f"kernel/dw_{variant}", us,
                     f"launches={launches};eager_dispatch"))
        RECORDS.append({"op": "masked_dw", "variant": variant, "shape": shape,
                        "ratio": n_sel / nb, "us": us, "launches": launches,
                        "timing": "eager_dispatch", **rl})

    k_steps, r = 3, 64
    w = jnp.asarray(rng.normal(size=(k_steps, r, n)), jnp.float32)
    upd = jnp.asarray(rng.normal(size=(k_steps, r, s, n_sel, blk)),
                      jnp.float32)
    idx2 = jnp.asarray(
        np.stack([[rng.choice(nb, n_sel, replace=False) for _ in range(s)]
                  for _ in range(k_steps)]), jnp.int32)

    def sc_fused(w, upd, idx2):
        return block_scatter_update_kernel(w, upd, idx2, tr=r, interpret=True)

    def sc_loop(w, upd, idx2):        # PR 1: one launch per (K, shard)
        outs = []
        for kk in range(k_steps):
            shards = [block_scatter_update_kernel(
                w[kk:kk + 1, :, si * loc:(si + 1) * loc],
                upd[kk:kk + 1, :, si:si + 1], idx2[kk:kk + 1, si:si + 1],
                tr=r, interpret=True) for si in range(s)]
            outs.append(jnp.concatenate(shards, axis=2))
        return jnp.concatenate(outs, axis=0)

    shape = f"K{k_steps}r{r}s{s}nb{nb}b{blk}"
    elems = k_steps * r * s * n_sel * blk        # touched weight elements
    rl = kernel_roofline(1.0 * elems, 4.0 * 3 * elems)
    for variant, fn in (("fused", sc_fused), ("per_k_shard_loop", sc_loop)):
        us = _time(fn, w, upd, idx2, n=3)        # eager: dispatch per launch
        launches = _launches(fn, w, upd, idx2)
        rows.append((f"kernel/writeback_{variant}", us,
                     f"launches={launches};eager_dispatch"))
        RECORDS.append({"op": "block_scatter_update", "variant": variant,
                        "shape": shape, "ratio": n_sel / nb, "us": us,
                        "launches": launches, "timing": "eager_dispatch",
                        **rl})

    # fused optimizer: one in-place launch vs jnp gather -> rule -> scatter
    from functools import partial

    from repro.kernels.fused_block_opt import fused_block_opt_kernel
    g = jnp.asarray(rng.normal(size=(k_steps, r, s, n_sel, blk)), jnp.float32)
    mu = jnp.zeros((k_steps, r, n), jnp.float32)
    lr, t = jnp.float32(0.05), jnp.float32(1.0)

    def opt_fused(w, g, idx2, lr, t, mu):
        return fused_block_opt_kernel(w, g, idx2, lr, t, mu, kind="momentum",
                                      momentum=0.9, tr=r, interpret=True)

    opt_jnp = jax.jit(partial(ref.fused_block_opt_ref, kind="momentum",
                              momentum=0.9))
    rl = kernel_roofline(4.0 * elems, 4.0 * 5 * elems)  # mu+w rmw per elem
    for variant, fn, jfn in (("fused", opt_fused, jax.jit(opt_fused)),
                             ("gather_jnp_scatter", None, opt_jnp)):
        us = _time(jfn, w, g, idx2, lr, t, mu)
        launches = _launches(fn, w, g, idx2, lr, t, mu) if fn else 0
        rows.append((f"kernel/block_opt_{variant}", us,
                     f"launches={launches}"))
        RECORDS.append({"op": "fused_block_opt", "variant": variant,
                        "shape": shape, "ratio": n_sel / nb, "us": us,
                        "launches": launches, "timing": "jit", **rl})
    return rows


def train_step_comparison() -> list[tuple]:
    """Dense-scatter vs compact-gradient jitted train step on the llama3
    smoke config: per-step wall time plus the compiler's temp-allocation
    estimate (the buffer class holding gradient scratch), and the static
    kernel-launch-site count of the kernels-enabled compact step (constant
    in the trainable-layer count K — the fused-path guarantee)."""
    from repro.configs import (OptimizerConfig, ShapeConfig,
                               SparseUpdateConfig, TrainConfig,
                               get_smoke_config)
    from repro.core.sparse_update import use_kernels
    from repro.train import make_train_state, make_train_step

    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("bench", 64, 8, "train")
    tc = TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=0.25, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="momentum", momentum=0.9,
                                  learning_rate=0.05))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (shape.global_batch, shape.seq_len),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2),
                                          (shape.global_batch, shape.seq_len),
                                          0, cfg.vocab_size)}
    rows = []
    for label, compact in (("dense_scatter", False), ("compact", True)):
        step = jax.jit(make_train_step(tc, plan, compact_grads=compact))
        # compile once (AOT) and run the compiled executable directly
        compiled = step.lower(state, batch).compile()
        try:
            mem = compiled.memory_analysis()
            temp = int(getattr(mem, "temp_size_in_bytes", 0))
        except Exception:
            temp = 0
        s, m = compiled(state, batch)      # warm up
        jax.block_until_ready(jax.tree.leaves(s))
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            s, m = compiled(s, batch)
        jax.block_until_ready(jax.tree.leaves(s))
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"train_step/{label}", us,
                     f"temp_bytes={temp};loss={float(m['loss']):.4f}"))
        RECORDS.append({"op": "train_step", "variant": label, "shape": "llama3-smoke",
                        "ratio": 0.25, "us": us, "launches": 0,
                        "temp_bytes": temp})
    step_k = make_train_step(tc, plan, compact_grads=True)
    with use_kernels(True):
        launches = kernel_launch_count(jax.make_jaxpr(step_k)(state, batch))
    rows.append(("train_step/compact_kernels_launch_sites", launches,
                 "constant_per_selectable_leaf"))
    RECORDS.append({"op": "train_step", "variant": "compact_kernels",
                    "shape": "llama3-smoke", "ratio": 0.25, "us": 0.0,
                    "launches": launches})
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
