"""Kernel microbenchmarks: jnp oracle vs Pallas(interpret) wall time on CPU
(correctness-path timing only — TPU timing requires hardware), plus the
compute-skip ratio the block-sparse dW kernel achieves by construction, and
a dense-scatter vs compact-gradient train-step comparison (step time and
compiler-reported peak temp memory)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.masked_dw import block_sparse_dw_kernel


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[tuple]:
    rows = []
    m, k, n, block = 512, 256, 512, 64
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(np.random.default_rng(1).normal(size=(m, n)), jnp.float32)
    for ratio in (0.125, 0.25, 0.5, 1.0):
        n_sel = max(1, int(n // block * ratio))
        idx = jnp.arange(n_sel, dtype=jnp.int32)
        jr = jax.jit(lambda x, dy, idx: ref.block_sparse_dw_ref(x, dy, idx, block))
        t_ref = _time(jr, x, dy, idx)
        flops_skip = 1.0 - n_sel / (n // block)
        rows.append((f"kernel/masked_dw_r{ratio}", t_ref,
                     f"jnp_oracle;compute_skipped={flops_skip:.0%}"))
    # dense dW for comparison
    jd = jax.jit(lambda x, dy: jnp.einsum("mk,mn->kn", x, dy))
    rows.append(("kernel/dense_dw", _time(jd, x, dy), "baseline"))
    rows += train_step_comparison()
    return rows


def train_step_comparison() -> list[tuple]:
    """Dense-scatter vs compact-gradient jitted train step on the llama3
    smoke config: per-step wall time plus the compiler's temp-allocation
    estimate (the buffer class holding gradient scratch)."""
    from repro.configs import (OptimizerConfig, ShapeConfig,
                               SparseUpdateConfig, TrainConfig,
                               get_smoke_config)
    from repro.train import make_train_state, make_train_step

    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("bench", 64, 8, "train")
    tc = TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=0.25, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="momentum", momentum=0.9,
                                  learning_rate=0.05))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (shape.global_batch, shape.seq_len),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2),
                                          (shape.global_batch, shape.seq_len),
                                          0, cfg.vocab_size)}
    rows = []
    for label, compact in (("dense_scatter", False), ("compact", True)):
        step = jax.jit(make_train_step(tc, plan, compact_grads=compact))
        # compile once (AOT) and run the compiled executable directly
        compiled = step.lower(state, batch).compile()
        try:
            mem = compiled.memory_analysis()
            temp = int(getattr(mem, "temp_size_in_bytes", 0))
        except Exception:
            temp = 0
        s, m = compiled(state, batch)      # warm up
        jax.block_until_ready(jax.tree.leaves(s))
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            s, m = compiled(s, batch)
        jax.block_until_ready(jax.tree.leaves(s))
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"train_step/{label}", us,
                     f"temp_bytes={temp};loss={float(m['loss']):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
