"""Kernel microbenchmarks: jnp oracle vs Pallas(interpret) wall time on CPU
(correctness-path timing only — TPU timing requires hardware), plus the
compute-skip ratio the block-sparse dW kernel achieves by construction."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.masked_dw import block_sparse_dw_kernel


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[tuple]:
    rows = []
    m, k, n, block = 512, 256, 512, 64
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(np.random.default_rng(1).normal(size=(m, n)), jnp.float32)
    for ratio in (0.125, 0.25, 0.5, 1.0):
        n_sel = max(1, int(n // block * ratio))
        idx = jnp.arange(n_sel, dtype=jnp.int32)
        jr = jax.jit(lambda x, dy, idx: ref.block_sparse_dw_ref(x, dy, idx, block))
        t_ref = _time(jr, x, dy, idx)
        flops_skip = 1.0 - n_sel / (n // block)
        rows.append((f"kernel/masked_dw_r{ratio}", t_ref,
                     f"jnp_oracle;compute_skipped={flops_skip:.0%}"))
    # dense dW for comparison
    jd = jax.jit(lambda x, dy: jnp.einsum("mk,mn->kn", x, dy))
    rows.append(("kernel/dense_dw", _time(jd, x, dy), "baseline"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
