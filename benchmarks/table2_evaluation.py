"""Paper Table II analogue: No-FT / Last / Full / Fixed / Dynamic on the
synthetic MobileNetV2 transfer task, with the memory model's 'extra memory'
column.

The paper's numbers (CIFAR-10, 256KB): 36.83 / 59.34 / 90.33 / 84.3 / 85.77.
We validate the ORDERING and the memory ratios, not ImageNet absolutes
(no datasets ship offline; DESIGN.md §8.5).
"""
from __future__ import annotations

import time

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import OptimizerConfig, SparseUpdateConfig
from repro.configs.mobilenetv2_cifar import smoke_config
from repro.core.act_prune import make_act_pruner
from repro.data.synthetic import TransferTask
from repro.models import mobilenet_v2 as MN
from repro.optim import apply_updates, init_opt_state

STEPS = 120
BATCH = 32
EVAL_BATCHES = 6
# 3-phase schedule (paper: 10/20/20 epochs -> steps here)
PHASE_J, PHASE_K = 30, 60
UPDATE_RATIO = 0.2
LAST_K_CONVS = 6
BLOCK = 4


def _eval(cfg, task, p, n=EVAL_BATCHES):
    accs = []
    for s in range(n):
        b = task.batch(64, 10_000 + s, "target")
        _, m = MN.loss_fn(cfg, (None, p), {
            "images": jnp.asarray(b["images"]),
            "labels": jnp.asarray(b["labels"])})
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


def _pretrain(cfg, task, steps=150):
    """Stand-in for ImageNet pretraining: train on the 'pretrain' domain."""
    p = MN.init_params(cfg, jax.random.PRNGKey(0))
    oc = OptimizerConfig(kind="momentum", momentum=0.9, learning_rate=0.05,
                         warmup_steps=10, decay_steps=steps)
    st = init_opt_state(oc, p)
    grad = jax.jit(jax.value_and_grad(
        lambda p, b: MN.loss_fn(cfg, (None, p), b)[0]))
    upd = jax.jit(lambda p, g, s, t: apply_updates(oc, p, g, s, t))
    for step in range(steps):
        b = task.batch(BATCH, step, "pretrain")
        _, g = grad(p, {"images": jnp.asarray(b["images"]),
                        "labels": jnp.asarray(b["labels"])})
        p, st = upd(p, g, st, step)
    return p


def _selection(cfg, params, ratio, last_k, key, magnitude=True):
    """Per-conv output-channel-block selection for the last-K convs."""
    from repro.core.sparse_update import SelSpec
    names = MN.conv_layer_names(cfg)[-last_k:]
    idx, spec = {}, {}
    for name in names:
        node = params
        for part in name.split("/")[:-1]:
            node = node[part]
        w = node[name.split("/")[-1]]
        out = w.shape[-1]
        block = BLOCK if out % BLOCK == 0 else 1
        nb = out // block
        ns = max(1, int(round(ratio * nb)))
        sp = SelSpec(block=block, n_shards=1, n_sel=ns, n_blocks=nb)
        spec[name] = sp
        if magnitude:
            norms = np.asarray(jnp.abs(w).reshape(-1, nb, block).sum((0, 2)))
            sel = np.argsort(-norms)[:ns]
        else:
            sel = jax.random.choice(
                jax.random.fold_in(key, zlib.crc32(name.encode()) % 2**31),
                                    nb, (ns,), replace=False)
        idx[name] = jnp.asarray(sel, jnp.int32)[None, :]
    return idx, spec


def _transfer(cfg, task, pretrained, method: str):
    """Run one Table-II row; returns (acc, extra_memory_bytes)."""
    lr = 0.01 if method == "full" else 0.03   # full FT needs the smaller lr
    oc = OptimizerConfig(kind="momentum", momentum=0.9, learning_rate=lr,
                         warmup_steps=12, decay_steps=STEPS)
    act_prune = make_act_pruner(0.15, 2)
    key = jax.random.PRNGKey(7)
    conv_names = MN.conv_layer_names(cfg)

    if method == "none":
        return _eval(cfg, task, pretrained), 0

    # frozen/trainable split
    trainable = {}
    frozen = dict(pretrained)
    if method == "last":
        trainable = {"classifier": pretrained["classifier"]}
        frozen = {k: v for k, v in pretrained.items() if k != "classifier"}
    elif method == "full":
        trainable, frozen = dict(pretrained), None
    else:  # fixed / dynamic: classifier + last-K convs (GN frozen — paper)
        keep = set()
        for n in conv_names[-LAST_K_CONVS:]:
            keep.add(n.split("/")[0])
        trainable = {k: pretrained[k] for k in keep | {"classifier"}}
        frozen = {k: v for k, v in pretrained.items() if k not in trainable}

    idx = spec = None
    if method in ("fixed", "dynamic"):
        idx, spec = _selection(cfg, pretrained, UPDATE_RATIO, LAST_K_CONVS, key)

    st = init_opt_state(oc, trainable)

    def loss(tr, batch, idx):
        sel = (idx, spec) if idx is not None else None   # spec is static
        return MN.loss_fn(cfg, (frozen, tr), batch, sel=sel,
                          act_prune=act_prune)[0]

    grad = jax.jit(jax.value_and_grad(loss))
    upd = jax.jit(lambda p, g, s, t: apply_updates(oc, p, g, s, t))
    p = trainable
    for step in range(STEPS):
        if method == "dynamic" and PHASE_J <= step < PHASE_J + PHASE_K:
            idx, _ = _selection(cfg, pretrained, UPDATE_RATIO, LAST_K_CONVS,
                                jax.random.fold_in(key, step), magnitude=False)
        b = task.batch(BATCH, step, "target")
        _, g = grad(p, {"images": jnp.asarray(b["images"]),
                        "labels": jnp.asarray(b["labels"])}, idx)
        p, st = upd(p, g, st, step)

    merged = dict(frozen or {})
    merged.update(p)
    # extra memory = trainable grads (+selected-only for sparse) + momentum
    n_tr = sum(x.size for x in jax.tree.leaves(p))
    ratio = UPDATE_RATIO if method in ("fixed", "dynamic") else 1.0
    extra = int(n_tr * ratio * 4 * 2)
    return _eval(cfg, task, merged), extra


def run() -> list[tuple]:
    cfg = smoke_config()
    task = TransferTask(img=cfg.img_size, seed=0)
    pre = _pretrain(cfg, task)
    rows = []
    for method in ("none", "last", "full", "fixed", "dynamic"):
        t0 = time.perf_counter()
        acc, extra = _transfer(cfg, task, pre, method)
        rows.append((f"table2/{method}", (time.perf_counter() - t0) * 1e6,
                     f"acc={acc:.4f};extra_mem={extra}B"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
