"""Paper Fig. 2 analogue: at the SAME memory budget, updating MORE (later)
layers at a small channel ratio beats updating fewer layers densely.

LM version (llama3-smoke): last-1 layer @ r=1.0 vs last-4 layers @ r=0.25
(equal updated-parameter budget), identical steps/optimizer.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (OptimizerConfig, ShapeConfig, SparseUpdateConfig,
                           TrainConfig, get_smoke_config)
from repro.data import lm_batches
from repro.train import make_train_state, make_train_step

STEPS = 60


def _run(num_layers: int, ratio: float, arch="llama3-8b", smoke_layers=4):
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch), num_layers=smoke_layers)
    shape = ShapeConfig("t", 16, 16, "train")
    tc = TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=ratio,
                                  num_update_layers=num_layers,
                                  channel_block=8, phase_fixed_early=10,
                                  phase_dynamic=30),
        optimizer=OptimizerConfig(kind="adamw", learning_rate=3e-3))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(tc, plan))
    losses = []
    for i, b in zip(range(STEPS), lm_batches(16, 16, cfg.vocab_size, seed=5)):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    from repro.core import selected_fraction
    return float(np.mean(losses[-10:])), selected_fraction(plan, cfg)


def run() -> list[tuple]:
    rows = []
    t0 = time.perf_counter()
    deep_loss, deep_frac = _run(num_layers=4, ratio=0.25)
    shallow_loss, shallow_frac = _run(num_layers=1, ratio=1.0)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig2/last4_r0.25", dt / 2,
                 f"final_loss={deep_loss:.4f};param_frac={deep_frac:.4f}"))
    rows.append(("fig2/last1_r1.0", dt / 2,
                 f"final_loss={shallow_loss:.4f};param_frac={shallow_frac:.4f}"))
    rows.append(("fig2/more_layers_wins", 0.0,
                 f"{deep_loss:.4f}<={shallow_loss + 0.02:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
