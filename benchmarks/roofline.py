"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell, TPU v5e constants:

    compute    = FLOPs_per_device / 197e12          (bf16 MXU peak)
    memory     = HBM_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9 (per-link ICI)

FLOPs source: XLA cost_analysis counts while bodies ONCE (verified —
launch/hlo_analysis docstring), so the compute/memory terms use the
ANALYTIC per-step model (6·N_active·D for train + attention/recompute
terms), which tests/test_roofline.py validates against unrolled HLO on
small configs. Collective bytes ARE trip-count-corrected from the
partitioned HLO (launch/hlo_analysis.collective_bytes).

Memory-bytes caveat: the CPU dry-run backend upcasts bf16 while-carries to
f32 and double-buffers loop state, inflating 'bytes accessed' ~2x vs TPU;
the analytic bytes model is used for the memory term, with the HLO number
reported alongside.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass

from repro.configs import SHAPES, cell_is_skipped, get_config
from repro.models import transformer as T
from repro.models.registry import param_count

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link
CHIPS_SINGLE = 256


# ---------------------------------------------------------------------------
# analytic per-device FLOPs / bytes
# ---------------------------------------------------------------------------

def _attn_flops(cfg, seq: int, tokens: int, causal: bool = True) -> float:
    """Score+PV matmul FLOPs for all layers over `tokens` tokens."""
    if cfg.family == "ssm":
        # rwkv wkv: per token per head: 2 * D*D mults for S update + out
        hd = cfg.rwkv.head_dim
        h = cfg.d_model // hd
        return cfg.num_layers * tokens * h * hd * hd * 4.0
    hd = cfg.resolved_head_dim
    n_attn = cfg.num_layers
    local_frac = 0.0
    window = 0
    if cfg.attn_pattern.startswith("local_global"):
        _, l, g = cfg.attn_pattern.split(":")
        local_frac = int(l) / (int(l) + int(g))
        window = cfg.sliding_window
    if cfg.attn_every:
        n_attn = cfg.num_layers // cfg.attn_every
    eff_k_full = seq / 2 if causal else seq
    eff_k_local = min(window, seq) if window else eff_k_full
    per_tok = 4.0 * cfg.num_heads * hd  # qk + pv, x2 for mult-add
    full_layers = n_attn * (1 - local_frac)
    local_layers = n_attn * local_frac
    return tokens * per_tok * (full_layers * eff_k_full +
                               local_layers * eff_k_local)


def analytic_cell(arch: str, shape_name: str, chips: int = CHIPS_SINGLE,
                  trainable_fraction: float = 0.25,
                  update_ratio: float = 0.2) -> dict:
    """Per-device analytic FLOPs and HBM bytes for one step of the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = param_count(cfg, active_only=True)
    n_total = param_count(cfg)

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # forward everywhere; backward(dx+dw) over the trainable suffix with
        # remat (+1 recompute fwd); dW skipped outside selected blocks.
        fwd = 2.0 * n_active * tokens
        bwd_dx = 2.0 * n_active * tokens * trainable_fraction
        bwd_dw = 2.0 * n_active * tokens * trainable_fraction * update_ratio
        remat = 2.0 * n_active * tokens * trainable_fraction
        attn = _attn_flops(cfg, shape.seq_len, tokens) * (
            1.0 + 3.0 * trainable_fraction)   # fwd + (remat+dq/dk/dv) on suffix
        flops = fwd + bwd_dx + bwd_dw + remat + attn
        # HBM: params read (fwd + trainable bwd), activations save+read,
        # grads write+read
        bytes_ = (n_total * 2 * (1 + trainable_fraction)
                  + tokens * cfg.d_model * 2 * 3 * _depth(cfg) * trainable_fraction
                  + n_total * trainable_fraction * update_ratio * 2 * 2)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens + _attn_flops(cfg, shape.seq_len, tokens)
        bytes_ = n_total * 2 + tokens * cfg.d_model * 2 * 4
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2.0 * n_active * tokens + _attn_flops(
            cfg, shape.seq_len, tokens, causal=False)
        # decode is memory-bound: read all params + the whole KV cache
        bytes_ = n_total * 2 + _cache_bytes(cfg, shape) + tokens * cfg.d_model * 2
    return {
        "flops_per_device": flops / chips,
        "bytes_per_device": bytes_ / chips,
        "model_flops": (6.0 if shape.kind == "train" else 2.0) * n_active * tokens,
        "tokens": tokens,
    }


def _depth(cfg) -> int:
    return cfg.num_layers


def _cache_bytes(cfg, shape) -> float:
    if cfg.family == "ssm":
        hd = cfg.rwkv.head_dim
        h = cfg.d_model // hd
        return cfg.num_layers * shape.global_batch * h * hd * hd * 4
    hd = cfg.resolved_head_dim
    n_attn = cfg.num_layers
    window_frac, window = 0.0, 0
    if cfg.attn_pattern.startswith("local_global"):
        _, l, g = cfg.attn_pattern.split(":")
        window_frac = int(l) / (int(l) + int(g))
        window = cfg.sliding_window
    if cfg.attn_every:
        n_attn = cfg.num_layers // cfg.attn_every
        ssm_bytes = (cfg.num_layers - n_attn) * shape.global_batch * \
            cfg.ssm.expand * cfg.d_model * cfg.ssm.d_state * 4
    else:
        ssm_bytes = 0.0
    full = n_attn * (1 - window_frac) * shape.seq_len
    local = n_attn * window_frac * min(window or shape.seq_len, shape.seq_len)
    return (full + local) * shape.global_batch * cfg.num_kv_heads * hd * 2 * 2 \
        + ssm_bytes


# ---------------------------------------------------------------------------
# table builder
# ---------------------------------------------------------------------------

def load_dryrun(out_dir: str, arch: str, shape: str, mesh: str = "single",
                mode: str = "sparse") -> dict | None:
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}__{mode}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_row(arch: str, shape: str, out_dir: str = "experiments/dryrun",
                 mode: str = "sparse") -> dict:
    skip = cell_is_skipped(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape, "status": "SKIP",
                "skip_reason": skip}
    rec = load_dryrun(out_dir, arch, shape, "single", mode)
    if rec is None or rec.get("status") != "OK":
        return {"arch": arch, "shape": shape, "status": "MISSING"}
    ana = analytic_cell(arch, shape)
    t_compute = ana["flops_per_device"] / PEAK_FLOPS
    t_memory = ana["bytes_per_device"] / HBM_BW
    wire = rec.get("collective_wire_bytes_per_device")
    if wire is None:
        # older records used operand-byte accounting: ring all-reduce moves
        # ~2x its operand on the wire; other ops ~1x.
        by = rec.get("collective_bytes_by_op", {})
        ar = by.get("all-reduce", 0)
        wire = rec["collective_bytes_per_device"] + ar
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    mfu = (ana["model_flops"] / CHIPS_SINGLE / PEAK_FLOPS) / step_time
    return {
        "arch": arch, "shape": shape, "status": "OK",
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": ana["model_flops"],
        "hlo_flops_body_once_per_dev": rec["hlo_flops_body_once"],
        "analytic_flops_per_dev": ana["flops_per_device"],
        "useful_ratio": ana["model_flops"] / CHIPS_SINGLE /
        max(ana["flops_per_device"], 1),
        "roofline_fraction": mfu,
        "temp_bytes_dev": rec["memory"]["temp_size_in_bytes"],
        "arg_bytes_dev": rec["memory"]["argument_size_in_bytes"],
        "collective_by_op": rec.get("collective_bytes_by_op", {}),
    }


def full_table(out_dir: str = "experiments/dryrun", mode: str = "sparse"):
    from repro.configs import ARCH_IDS
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            rows.append(roofline_row(arch, shape, out_dir, mode))
    return rows


# ---------------------------------------------------------------------------
# kernel-level roofline context + benchmarks.run hook
# ---------------------------------------------------------------------------

RECORDS: list[dict] = []      # machine-readable output (BENCH_roofline.json)
BENCH_JSON = "BENCH_roofline.json"

RIDGE = PEAK_FLOPS / HBM_BW   # flops/byte at the compute/memory corner


def kernel_roofline(flops: float, bytes_: float) -> dict:
    """Classify one kernel (or cell) by arithmetic intensity against the
    TPU v5e ridge point PEAK_FLOPS/HBM_BW: below it the kernel is
    memory-bound, above it compute-bound. `benchmarks.kernel_micro` merges
    this into every BENCH_kernels.json record next to the launch count."""
    ai = float(flops) / max(float(bytes_), 1.0)
    return {
        "flops": float(flops),
        "bytes": float(bytes_),
        "arith_intensity": ai,
        "ridge_flops_per_byte": RIDGE,
        "bound": "compute" if ai >= RIDGE else "memory",
    }


def run() -> list[tuple]:
    """benchmarks.run hook: one row per non-skipped (arch, shape) cell.
    Uses the dry-run artifacts when present (status OK: three-term
    bottleneck incl. collectives); falls back to the analytic FLOPs/bytes
    model alone (status ANALYTIC) so a fresh checkout still gets the
    compute-vs-memory classification."""
    RECORDS.clear()
    rows = []
    for r in full_table():
        if r["status"] == "SKIP":
            continue
        arch, shape = r["arch"], r["shape"]
        ana = analytic_cell(arch, shape)
        ctx = kernel_roofline(ana["flops_per_device"],
                              ana["bytes_per_device"])
        rec = {"arch": arch, "shape": shape, **ctx}
        if r["status"] == "OK":
            rec.update(status="OK", bottleneck=r["bottleneck"],
                       t_compute_s=r["t_compute_s"],
                       t_memory_s=r["t_memory_s"],
                       t_collective_s=r["t_collective_s"],
                       roofline_fraction=r["roofline_fraction"])
            derived = f"bound={r['bottleneck']};ai={ctx['arith_intensity']:.2f}"
        else:
            rec.update(status="ANALYTIC", bottleneck=ctx["bound"])
            derived = (f"bound={ctx['bound']};"
                       f"ai={ctx['arith_intensity']:.2f};analytic_only")
        RECORDS.append(rec)
        rows.append((f"roofline/{arch}__{shape}", 0.0, derived))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mode", default="sparse")
    args = ap.parse_args()
    rows = full_table(args.out, args.mode)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'bound':>10s} {'MFU':>6s} {'useful':>7s}")
    print(hdr)
    for r in rows:
        if r["status"] != "OK":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['status']}"
                  + (f" ({r.get('skip_reason','')})" if r["status"] == "SKIP" else ""))
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{1e3*r['t_compute_s']:9.2f} {1e3*r['t_memory_s']:9.2f} "
              f"{1e3*r['t_collective_s']:9.2f} {r['bottleneck']:>10s} "
              f"{r['roofline_fraction']:6.1%} {r['useful_ratio']:7.2f}")


if __name__ == "__main__":
    main()
