# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark runner — one module per paper table/figure:

    table2_evaluation  Paper Table II (No-FT/Last/Full/Fixed/Dynamic + memory)
    fig2_layer_depth   Paper Fig. 2  (more later layers @ same budget wins)
    fig4_weights_updated Paper Fig. 4 (coverage: dynamic >> fixed; ~2%/iter)
    pruning_table      Paper §IV-B   (channel/pattern sparsity, FLOPs)
    memory_table       Paper's 98% feature-memory claim, per-arch
    kernel_micro       Pallas kernel oracles + fused-vs-loop + skip ratios
    roofline           per-(arch,shape) bound classification + arith intensity

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Modules that expose a BENCH_JSON name and a RECORDS list (kernel_micro ->
BENCH_kernels.json) additionally get their machine-readable records dumped
to that file at the repo root, so the perf trajectory is tracked across PRs.
"""
import argparse
import importlib
import json
import pathlib
import sys
import traceback

_ROOT = pathlib.Path(__file__).resolve().parent.parent

MODULES = [
    "fig4_weights_updated",
    "pruning_table",
    "memory_table",
    "kernel_micro",
    "roofline",
    "fig2_layer_depth",
    "table2_evaluation",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failed = 0
    for name in mods:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
            json_name = getattr(mod, "BENCH_JSON", None)
            records = getattr(mod, "RECORDS", None)
            if json_name and records:
                path = _ROOT / json_name
                path.write_text(json.dumps(records, indent=1) + "\n")
                print(f"# wrote {path}", file=sys.stderr, flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name},0,ERROR", flush=True)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
