"""Serving throughput benchmark: honest tok/s + latency + page-pool stats.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --arch llama3-8b --smoke --requests 7 --batch 4

Counts come straight from the paged engine's accounting: completed
requests and their tokens only — padded/free slots never inflate either
number, and neither do cancelled or timed-out requests (`--cancel-frac`
cancels a fraction of requests mid-stream to prove it: requests=7,
batch=4, cancel-frac 0 reports exactly 7 requests and 7 * gen_len
tokens). Alongside tok/s and p50/p95 latency the benchmark reports
page-pool utilization (peak pages / pool pages) and the prompt-prefix
hit rate; `--shared-prefix-len N` runs the system-prompt workload where
sharing shows up as hit rate > 0 and a LOWER page peak than
`--no-prefix-sharing` on the same workload. `--branching-prefix` runs
the zipf-branching partially-overlapping prefix workload (prompts agree
for a random number of pages, then diverge) — the radix tree's home
turf — and, in radix mode, a third stats line reports tree node count,
snapshot hit rate, and spill/rehydrate counts.

`--arch all` sweeps the four cache families (dense KV, ring-buffer, rwkv
state, hybrid mamba state).

`--personalize-frac F` routes the first F fraction of requests through the
per-user delta store (round-robin user ids; `--users 2` implied when unset)
and reports the personalization overheads next to throughput: delta-store
hit rate, resident delta bytes, and online-train-wave seconds per decoded
token. Train-wave accounting is exact: one wave per COMPLETED personalized
request (cancelled ones never train), asserted below.

Warmup: one throwaway run triggers compilation so the timed run measures
steady-state serving, not XLA.

Chaos modes (CI smoke for the robustness layer):

- `--fault-rate R --chaos-seed S` first serves the workload on a
  fault-free oracle engine, then on an engine injecting deterministic
  faults; completed requests must be TOKEN-IDENTICAL to the oracle, and
  every submitted request must be accounted for (completed + cancelled +
  quarantined, with a result recorded) — faults may delay requests but
  can never corrupt them or drop them silently.
- `--kill-after N` crashes the engine after N completed requests, then
  restarts it against the same journal + persisted prefix tier: every
  journaled in-flight request must complete token-identically on replay,
  and the restarted run must report prefix hits > 0 (warm restart).
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.serve import add_serve_args, build_engine, build_requests

FAMILY_ARCHS = ("llama3-8b", "gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b")


def _attach_cancels(requests, frac: float, gen_len: int):
    """Give the first `frac` fraction of requests a streaming callback that
    cancels after gen_len // 2 tokens — their tokens must never reach the
    throughput counters."""
    n_cancel = int(len(requests) * frac)
    cut = max(1, gen_len // 2)
    for req in requests[:n_cancel]:
        seen = {"n": 0}

        def stop(rid, tok, seen=seen):
            seen["n"] += 1
            return seen["n"] < cut
        req.stream = stop
    return n_cancel


def _attach_users(requests, frac: float, num_users: int):
    """First `frac` fraction of requests keep a round-robin user id; the
    rest serve the plain base model (mixed personalized/plain batch)."""
    n_pers = int(len(requests) * frac)
    for i, req in enumerate(requests):
        req.user = (i % num_users) if i < n_pers else None
    return n_pers


def _plain_ns(ns):
    """`ns` with every chaos knob off: the fault-free oracle config."""
    return argparse.Namespace(**{**vars(ns), "fault_rate": 0.0,
                                 "kill_after": None, "journal": None})


def _completed_tokens(stats):
    return {r.rid: list(r.tokens) for r in stats.results.values()
            if r.status == "completed"}


def bench_crash_restart(ns, arch: str):
    """--kill-after N: crash mid-run, restart against the same journal and
    persisted prefix tier, and prove warm idempotent replay."""
    from repro.runtime.chaos import InjectedCrash
    tmp = tempfile.mkdtemp(prefix="serve-crash-")
    if ns.journal is None:
        ns.journal = os.path.join(tmp, "journal.jsonl")
    if ns.prefix_persist is None:
        ns.prefix_persist = os.path.join(tmp, "spill")
    oracle_ns = argparse.Namespace(**{**vars(_plain_ns(ns)),
                                      "prefix_persist": None})
    cfg, oracle = build_engine(oracle_ns)
    ref = _completed_tokens(oracle.run(build_requests(oracle_ns, cfg)))
    cfg, engine = build_engine(ns)
    try:
        engine.run(build_requests(ns, cfg))
        raise AssertionError("--kill-after never crashed (fewer requests "
                             "completed than the kill threshold?)")
    except InjectedCrash as e:
        print(f"[{arch}] {e}")
    engine._journal.close()
    cfg, engine2 = build_engine(_plain_ns_keep_journal(ns))
    pending = engine2.recover_requests()
    assert pending, "crash left no journaled in-flight requests to replay"
    stats = engine2.run(pending)
    assert stats.requests_completed == len(pending), (
        "a journaled in-flight request did not complete on replay")
    assert stats.journal_replays == len(pending), (
        "journal replay accounting diverged from re-admissions")
    assert stats.prefix_hit_tokens > 0, (
        "restart was cold: no prefix hits from the persisted spill tier")
    for rid, toks in _completed_tokens(stats).items():
        assert toks == ref[rid], (
            f"rid {rid}: replayed tokens differ from the fault-free oracle")
    print(f"[{arch}] crash-restart: {len(pending)} journaled requests "
          f"replayed, {stats.journal_replays} journal_replays, "
          f"prefix_hit_tokens={stats.prefix_hit_tokens} (warm restart)")
    return stats


def _plain_ns_keep_journal(ns):
    out = _plain_ns(ns)
    out.journal = ns.journal
    return out


def bench_one(args, arch: str):
    ns = argparse.Namespace(**{**vars(args), "arch": arch})
    if ns.personalize_frac > 0 and ns.users == 0:
        ns.users = 2            # personalization needs a user universe
    if ns.kill_after is not None:
        return bench_crash_restart(ns, arch)
    chaos_mode = ns.fault_rate > 0.0
    ref = None
    if chaos_mode:
        # fault-free oracle first: same workload, chaos knobs off. The
        # oracle also absorbs compilation, so the chaos engine runs the
        # exact same jitted shapes.
        oracle_ns = _plain_ns(ns)
        cfg, oracle = build_engine(oracle_ns)
        oreqs = build_requests(oracle_ns, cfg)
        if ns.personalize_frac > 0:
            _attach_users(oreqs, ns.personalize_frac, ns.users)
        ref = _completed_tokens(oracle.run(oreqs))
    cfg, engine = build_engine(ns)
    if not chaos_mode:
        # warmup: compile the step shapes outside the timed run (skipped in
        # chaos mode — a warmup run would consume fault draws)
        warm = argparse.Namespace(**{**vars(ns),
                                     "requests": min(2, ns.requests),
                                     "seed": ns.seed + 1})
        engine.run(build_requests(warm, cfg))
    requests = build_requests(ns, cfg)
    if ns.personalize_frac > 0:
        n_pers = _attach_users(requests, ns.personalize_frac, ns.users)
    else:
        n_pers = len(requests) if ns.users > 0 else 0
    n_cancel = _attach_cancels(requests, args.cancel_frac, args.gen_len)
    stats = engine.run(requests)
    if chaos_mode:
        # graceful degradation contract: faults may delay or quarantine,
        # never corrupt or silently drop
        assert (stats.requests_completed + stats.requests_cancelled
                + stats.quarantined == len(requests)), (
            "request dropped without accounting under fault injection")
        assert len(stats.results) == len(requests), (
            "request left no result record under fault injection")
        for rid, toks in _completed_tokens(stats).items():
            assert toks == ref[rid], (
                f"rid {rid}: tokens diverged from the fault-free oracle")
        print(f"[{arch}] chaos: faults_injected={stats.faults_injected} "
              f"by_kind={dict(stats.faults_by_kind)} "
              f"retries={stats.retries} sheds={stats.sheds} "
              f"quarantined={stats.quarantined} "
              f"watchdog_kills={stats.watchdog_kills} "
              f"stream_errors={stats.stream_errors} "
              f"stragglers={stats.stragglers} (token parity vs oracle OK)")
    else:
        assert stats.requests_completed == len(requests) - n_cancel, (
            "cancelled requests leaked into completed-request accounting")
    if ns.users > 0 and not chaos_mode:
        # one online wave per COMPLETED personalized request, no more:
        # cancels attach to the same request prefix as user ids (under
        # chaos a quarantined personalized request legitimately skips its
        # wave, so the exact count only holds fault-free)
        assert stats.train_waves == n_pers - min(n_cancel, n_pers), (
            "train-wave count diverged from completed personalized requests")
    print(f"[{arch}] requests_completed={stats.requests_completed} "
          f"requests_cancelled={stats.requests_cancelled} "
          f"tokens_out={stats.tokens_out} "
          f"tokens_cancelled={stats.tokens_cancelled} "
          f"tok_s={stats.tok_per_s:.1f} "
          f"latency_p50_ms={stats.latency_p50_s * 1e3:.1f} "
          f"latency_p95_ms={stats.latency_p95_s * 1e3:.1f} "
          f"refills={stats.refills} "
          f"prefill_chunks={stats.prefill_chunks}")
    print(f"[{arch}] pages_peak={stats.pages_peak} "
          f"pages_total={stats.pages_total} "
          f"page_util={stats.page_util:.2f} "
          f"prefix_hit_rate={stats.prefix_hit_rate:.2f} "
          f"cow_splits={stats.cow_splits}")
    if stats.mesh_shards > 1:
        # page tables are replicated, so utilization is identical per shard;
        # resident pool bytes are what actually split across the mesh
        print(f"[{arch}] mesh_shards={stats.mesh_shards} "
              f"page_util_per_shard={stats.page_util:.2f} "
              f"pool_shard_bytes={stats.pool_shard_bytes}")
    if stats.prefix_mode == "radix":
        print(f"[{arch}] radix_nodes={stats.radix_nodes} "
              f"snapshot_hit_rate={stats.snapshot_hit_rate:.2f} "
              f"snapshots_stored={stats.snapshots_stored} "
              f"spills={stats.spills} "
              f"rehydrates={stats.rehydrates} "
              f"spill_entries={stats.spill_entries}")
    if ns.users > 0:
        print(f"[{arch}] personalize_frac={ns.personalize_frac} "
              f"users={ns.users} train_waves={stats.train_waves} "
              f"wave_ms_per_token={stats.train_wave_ms_per_token:.2f} "
              f"delta_hit_rate={stats.delta_hit_rate:.2f} "
              f"delta_resident_bytes={stats.delta_resident_bytes} "
              f"delta_evictions={stats.delta_evictions}")
    return stats


def _per_shard_prefill_flops_per_token(cfg, rules):
    """Analytic matmul FLOPs one shard spends per prefill token under the
    serve sharding policy: 2 * prod(LOCAL dims) summed over every rank >= 2
    weight leaf (sharded dims divided by the mesh width; the embedding
    table is a gather, not a matmul). With every layer tensor-parallel this
    drops ~1/N per shard as the mesh widens."""
    import jax

    from repro.models import decoding as D
    from repro.models import transformer as T

    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = D.paged_param_specs(cfg, params, rules)
    axis = rules.model_axis
    n = rules.mesh.shape[axis] if axis else 1
    total = 0
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: x is None)
    for (path, leaf), spec in zip(flat_p, flat_s):
        keys = [str(getattr(k, "key", "")) for k in path]
        if len(leaf.shape) < 2 or keys[-1] == "tok":
            continue
        size = 1
        for i, d in enumerate(leaf.shape):
            size *= d // n if (i < len(spec) and spec[i] is not None) else d
        total += 2 * size
    return total


def bench_mesh_sweep(args, arch: str):
    """--mesh-sweep: run the workload at every power-of-two model-axis
    width the host devices (and the arch's KV-head count) allow, and write
    one record per width into BENCH_kernels.json next to the kernel
    microbenchmarks. Each row splits prefill tok/s from decode tok/s and
    carries the analytic per-shard prefill FLOPs/token; --personalize-frac
    composes (deltas ride the sharded step), adding train-wave counts."""
    import json

    import jax

    from repro.launch.mesh import make_serve_mesh
    from repro.sharding import default_rules

    rows = []
    n = 1
    while n <= len(jax.devices()):
        ns = argparse.Namespace(**{**vars(args), "arch": arch,
                                   "mesh_model": n, "mesh_sweep": False})
        try:
            stats = bench_one(ns, arch)
        except ValueError as e:
            print(f"[{arch}] mesh{n}: skipped ({e})")
            n *= 2
            continue
        from repro.configs import get_config, get_smoke_config
        cfg = (get_smoke_config(ns.arch) if ns.smoke
               else get_config(ns.arch))
        flops = _per_shard_prefill_flops_per_token(
            cfg, default_rules(make_serve_mesh(n)))
        row = {
            "op": "serve_paged_decode",
            "variant": f"mesh{n}",
            "shape": f"{arch}-b{ns.batch}-p{ns.prompt_len}-g{ns.gen_len}",
            "mesh_shards": stats.mesh_shards,
            "tok_per_s": round(stats.tok_per_s, 2),
            "prefill_tok_per_s": round(stats.prefill_tok_per_s, 2),
            "decode_tok_per_s": round(stats.decode_tok_per_s, 2),
            "prefill_flops_per_tok_per_shard": flops,
            "page_util_per_shard": round(stats.page_util, 4),
            "pool_shard_bytes": stats.pool_shard_bytes,
        }
        if ns.personalize_frac > 0:
            row["personalize_frac"] = ns.personalize_frac
            row["train_waves"] = stats.train_waves
        rows.append(row)
        n *= 2
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_kernels.json")
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    keep = {(r["op"], r["variant"], r["shape"]) for r in rows}
    records = [r for r in records
               if (r.get("op"), r.get("variant"), r.get("shape")) not in keep]
    records.extend(rows)
    with open(path, "w") as f:
        f.write(json.dumps(records, indent=1))
    print(f"[{arch}] mesh sweep: {len(rows)} row(s) -> {path}")
    return rows


def main(argv=None):
    ap = add_serve_args(argparse.ArgumentParser())
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="fraction of requests cancelled mid-stream via "
                         "their streaming callback")
    ap.add_argument("--personalize-frac", type=float, default=0.0,
                    help="fraction of requests carrying a user id (per-user "
                         "delta decode + online train waves)")
    ap.add_argument("--mesh-sweep", action="store_true",
                    help="sweep --mesh-model over 1,2,4,... up to the host "
                         "device count and append serve_paged_decode rows "
                         "to BENCH_kernels.json")
    args = ap.parse_args(argv)
    archs = FAMILY_ARCHS if args.arch == "all" else (args.arch,)
    if args.mesh_sweep:
        return {arch: bench_mesh_sweep(args, arch) for arch in archs}
    return {arch: bench_one(args, arch) for arch in archs}


if __name__ == "__main__":
    main()
