"""Serving throughput benchmark: honest tok/s + per-request latency.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --arch llama3-8b --smoke --requests 7 --batch 4

Counts come straight from the continuous-batching engine's active-slot
accounting: `requests_completed` counts finished requests only and
`tokens_out` counts tokens sampled on active slots only — padded/free
slots never inflate either number (requests=7, batch=4 reports exactly
7 requests and 7 * gen_len tokens). `--arch all` sweeps the four cache
families (dense KV, ring-buffer, rwkv state, mamba/hybrid state).

Warmup: one throwaway run triggers compilation so the timed run measures
steady-state serving, not XLA.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import add_serve_args, build_engine
from repro.serve.engine import make_random_requests

FAMILY_ARCHS = ("llama3-8b", "gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b")


def bench_one(args, arch: str):
    ns = argparse.Namespace(**{**vars(args), "arch": arch})
    cfg, engine = build_engine(ns)
    # warmup: compile prefill/decode/insert outside the timed run
    engine.run(make_random_requests(cfg, min(2, args.requests),
                                    args.prompt_len, args.gen_len, seed=1))
    requests = make_random_requests(cfg, args.requests, args.prompt_len,
                                    args.gen_len, seed=args.seed)
    stats = engine.run(requests)
    print(f"[{arch}] requests_completed={stats.requests_completed} "
          f"tokens_out={stats.tokens_out} "
          f"tok_s={stats.tok_per_s:.1f} "
          f"latency_p50_ms={stats.latency_p50_s * 1e3:.1f} "
          f"latency_p95_ms={stats.latency_p95_s * 1e3:.1f} "
          f"refills={stats.refills}")
    return stats


def main(argv=None):
    ap = add_serve_args(argparse.ArgumentParser())
    args = ap.parse_args(argv)
    archs = FAMILY_ARCHS if args.arch == "all" else (args.arch,)
    return {arch: bench_one(args, arch) for arch in archs}


if __name__ == "__main__":
    main()
