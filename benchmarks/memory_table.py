"""Paper's 98%-feature-memory claim, scaled: per-device training extra
memory (activations + grads + opt state) for dense vs DGSU across the
full-size assigned archs, from the analytic memory model (validated against
the dry-run's memory_analysis; see EXPERIMENTS.md §Dry-run caveats)."""
from __future__ import annotations

import time

from repro.configs import ARCH_IDS, SHAPES, SparseUpdateConfig, get_config
from repro.core import memory as mem


def run() -> list[tuple]:
    rows = []
    shape = SHAPES["train_4k"]
    chips = 256
    for arch in ("llama3-8b", "command-r-35b", "musicgen-medium",
                 "deepseek-moe-16b"):
        cfg = get_config(arch)
        tokens_dev = shape.global_batch * shape.seq_len // chips
        sp = SparseUpdateConfig(update_ratio=0.2, channel_block=128)
        from repro.models.transformer import segment_layout
        total = sum(s.steps for s in segment_layout(cfg))
        k = max(1, total // 4)
        t0 = time.perf_counter()
        sparse = mem.training_extra_bytes(cfg, sp, k, tokens_dev)
        dense = mem.dense_training_extra_bytes(cfg, tokens_dev)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"memory/{arch}", dt,
                     f"sparse={sparse/2**20:.1f}MiB;dense={dense/2**20:.1f}MiB;"
                     f"saving={1 - sparse/dense:.2%}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
