"""Paper §IV-B analogue: channel + pattern pruning sparsity and FLOPs.

Paper numbers: channel pruning 3.5M->2.01M params (57.39% sparsity... the
paper's own wording mixes param-reduction and sparsity; we report both),
FLOPs 0.32G->0.15G (2.15x), channel+pattern sparsity ~92%."""
from __future__ import annotations

import time

import jax

from repro.configs.mobilenetv2_cifar import CONFIG, smoke_config
from repro.core import pruning
from repro.models import mobilenet_v2 as MN


def run() -> list[tuple]:
    cfg = smoke_config()
    params = MN.init_params(cfg, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    _, rep_ch = pruning.full_prune(params, cfg, channel_target=0.45,
                                   pattern=False, unstructured_rate=0.0)
    _, rep_all = pruning.full_prune(params, cfg, channel_target=0.45,
                                    pattern=True, unstructured_rate=0.6)
    dt = (time.perf_counter() - t0) * 1e6
    flops_dense = pruning.conv_flops(cfg, cfg.img_size)
    flops_pruned = flops_dense * (1 - rep_ch["conv_sparsity"])
    return [
        ("pruning/channel_sparsity", dt / 2,
         f"{rep_ch['conv_sparsity']:.4f}"),
        ("pruning/channel+pattern_sparsity", dt / 2,
         f"{rep_all['conv_sparsity']:.4f}"),
        ("pruning/flops_reduction", 0.0,
         f"{flops_dense/1e6:.1f}M->{flops_pruned/1e6:.1f}M "
         f"({flops_dense/max(flops_pruned,1):.2f}x)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
