"""Quickstart: dynamic gradient sparse update on a small LM in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: config -> plan -> 3-phase DGSU training ->
memory accounting vs dense.
"""
import jax
import jax.numpy as jnp

from repro.configs import (OptimizerConfig, ShapeConfig, SparseUpdateConfig,
                           TrainConfig, get_smoke_config)
from repro.core import memory as mem
from repro.core import selected_fraction
from repro.data import lm_batches
from repro.train import make_train_state, make_train_step


def main():
    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("quickstart", seq_len=32, global_batch=8, kind="train")
    sparse = SparseUpdateConfig(
        update_ratio=0.25,          # r: channel blocks per layer
        num_update_layers=2,        # K: last-2 layers trainable
        channel_block=16,
        phase_fixed_early=5,        # Algorithm 1: j / k / l
        phase_dynamic=20,
        phase_fixed_late=15,
    )
    tc = TrainConfig(model=cfg, shape=shape, sparse=sparse,
                     optimizer=OptimizerConfig(kind="adamw", learning_rate=3e-3),
                     steps=40)

    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    print(f"DGSU plan: trainable={plan.seg_trainable}, "
          f"{100 * selected_fraction(plan, cfg):.1f}% of params per iteration")
    tokens = shape.global_batch * shape.seq_len
    sp_b = mem.training_extra_bytes(cfg, sparse, 2, tokens)
    de_b = mem.dense_training_extra_bytes(cfg, tokens)
    print(f"training extra memory: sparse={sp_b/2**20:.2f}MiB "
          f"dense={de_b/2**20:.2f}MiB (saving {1 - sp_b/de_b:.0%})")

    step = jax.jit(make_train_step(tc, plan))
    data = lm_batches(shape.global_batch, shape.seq_len, cfg.vocab_size, seed=0)
    for i, batch in zip(range(tc.steps), data):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if (i + 1) % 10 == 0:
            phase = ("fixed-early" if i < 5 else
                     "DYNAMIC" if i < 25 else "fixed-late")
            print(f"step {i+1:3d} [{phase:11s}] loss={float(m['loss']):.4f}")
    print("done — see examples/edge_cnn_transfer.py for the paper's own "
          "MobileNetV2 experiment and launch/dryrun.py for the pod-scale path")


if __name__ == "__main__":
    main()
