"""The paper's memory-budget workflow at both scales: give DGSU a hard
byte budget and let the solver pick how many later layers fit (paper: fit
the backward pass in 256KB of MCU SRAM; here also: fit a fine-tune in a
TPU HBM slice).

    PYTHONPATH=src python examples/memory_budget.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import SparseUpdateConfig, get_config, get_smoke_config
from repro.core import memory as mem
from repro.models.transformer import segment_layout


def show(cfg, tokens_per_device, budgets, label):
    total = sum(s.steps for s in segment_layout(cfg))
    print(f"\n{label}: {cfg.name} ({total} scan blocks, "
          f"{tokens_per_device} tokens/device)")
    print(f"{'budget':>12s} {'last-K':>7s} {'extra-mem':>12s} {'vs dense':>9s}")
    dense = mem.dense_training_extra_bytes(cfg, tokens_per_device)
    for b in budgets:
        sp = SparseUpdateConfig(update_ratio=0.2, channel_block=128,
                                memory_budget_bytes=b)
        k = mem.solve_max_layers(cfg, sp, tokens_per_device)
        used = mem.training_extra_bytes(cfg, sp, k, tokens_per_device)
        print(f"{b/2**20:10.1f}MB {k:7d} {used/2**20:10.2f}MB "
              f"{used/dense:8.1%}")


def main():
    # edge scale: the paper's smoke-size CNN-ish budget on a small LM
    cfg = get_smoke_config("llama3-8b")
    show(cfg, tokens_per_device=256, budgets=[256 * 1024, 2**20, 8 * 2**20],
         label="edge scale (256KB .. 8MB)")
    # pod scale: llama3-8b full config, per-chip budgets
    cfg = get_config("llama3-8b")
    show(cfg, tokens_per_device=4096 * 16,
         budgets=[2 * 2**30, 4 * 2**30, 8 * 2**30],
         label="pod scale (2..8 GiB/chip for the backward working set)")


if __name__ == "__main__":
    main()
