"""The paper's own experiment, end to end: MobileNetV2 + GN transfer under a
memory budget with pruning, block activation pruning, and the 3-phase
dynamic gradient sparse update (Table II workflow).

    PYTHONPATH=src python examples/edge_cnn_transfer.py [--steps 120]

Pipeline (paper Fig. 1): pretrain (stand-in for ImageNet) -> channel +
pattern pruning ON THE PRETRAIN DATA -> transfer to the target domain with
No-FT / Last / Fixed / Dynamic / Full, reporting accuracy + extra memory.
"""
import argparse
import sys

sys.path.insert(0, "src")

from benchmarks import table2_evaluation as t2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    t2.STEPS = args.steps
    print("method,acc,extra_memory")
    for name, _us, derived in t2.run():
        print(f"{name.split('/')[1]},{derived}")
    print("\npaper Table II (CIFAR-10): none=36.83 last=59.34 full=90.33 "
          "fixed=84.30 dynamic=85.77 — validate ORDERING, not absolutes")


if __name__ == "__main__":
    main()
