"""Batched serving example: prefill + decode slots over a request queue.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b   # O(1)-state decode
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()
    serve_cli.main(["--arch", args.arch, "--smoke", "--requests", "8",
                    "--batch", "4", "--prompt-len", "24", "--gen-len", "8"])


if __name__ == "__main__":
    main()
