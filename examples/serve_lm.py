"""Batched serving example: continuous batching over a request queue.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b   # O(1)-state decode
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --temperature 0.8

With more requests than slots, finished slots are re-prefilled from the
queue mid-flight (watch the refill count in the summary line).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve_cli.main(["--arch", args.arch, "--smoke",
                    "--requests", str(args.requests), "--batch", "4",
                    "--prompt-len", "24", "--gen-len", "8",
                    "--temperature", str(args.temperature)])


if __name__ == "__main__":
    main()
