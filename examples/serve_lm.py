"""Batched serving example: paged continuous batching over a request queue.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b   # O(1)-state decode
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --temperature 0.8
    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b --stream
    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b --shared-prefix 16

With more requests than slots, finished slots are re-admitted from the
queue mid-flight (watch the refill count). `--shared-prefix N` gives every
request a common N-token prompt prefix — the prefix-hit rate and COW-split
counters in the summary show the paged cache sharing those pages. With
`--stream`, tokens print as they are sampled (requests interleave: that is
continuous batching in action).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stream", action="store_true")
    ap.add_argument("--shared-prefix", type=int, default=0)
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--smoke",
            "--requests", str(args.requests), "--batch", "4",
            "--prompt-len", "24", "--gen-len", "8", "--page-size", "8",
            "--temperature", str(args.temperature),
            "--shared-prefix-len", str(args.shared_prefix)]
    if args.stream:
        argv.append("--stream")
    serve_cli.main(argv)


if __name__ == "__main__":
    main()
