"""End-to-end driver: DGSU fine-tuning of a ~100M-param llama-family model
with checkpoint/restart, preemption handling, and straggler monitoring.

    PYTHONPATH=src python examples/train_lm_100m.py                 # quick (~25M, 60 steps)
    PYTHONPATH=src python examples/train_lm_100m.py --full          # ~100M, 300 steps

Kill it mid-run (Ctrl-C sends SIGINT; use SIGTERM for the grace path) and
rerun: it resumes from the latest checkpoint with an identical data stream.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import ModelConfig
from repro.launch import train as train_cli
from repro.models.registry import param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (hours on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    if args.full:
        cfg = ModelConfig(name="llama-100m", family="dense", num_layers=8,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32000, dtype="float32",
                          rope_theta=10_000.0)
        steps, batch, seq = 300, 8, 256
    else:
        cfg = ModelConfig(name="llama-25m", family="dense", num_layers=4,
                          d_model=384, num_heads=6, num_kv_heads=2,
                          d_ff=1024, vocab_size=8192, dtype="float32",
                          rope_theta=10_000.0)
        steps, batch, seq = 60, 8, 128

    n = param_count(cfg)
    print(f"model: {cfg.name} = {n/1e6:.1f}M params")

    # reuse the production launcher with an injected config
    import repro.configs.base as base
    base._MODULES["example-lm"] = "llama3_8b"   # module shim
    import repro.configs.llama3_8b as mod
    orig = mod.smoke_config
    mod.smoke_config = lambda: cfg
    try:
        train_cli.main([
            "--arch", "example-lm", "--smoke",
            "--steps", str(steps), "--batch", str(batch), "--seq", str(seq),
            "--optimizer", "adamw", "--lr", "1e-3",
            "--update-ratio", "0.25", "--update-layers", str(cfg.num_layers // 2),
            "--phase-j", str(steps // 6), "--phase-k", str(steps // 2),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20",
        ])
    finally:
        mod.smoke_config = orig


if __name__ == "__main__":
    main()
