"""Test-only helpers: a graceful fallback when `hypothesis` is absent.

The property tests use hypothesis when installed. Offline images may not
ship it; importing `given`/`settings`/`st` from here keeps the rest of each
test module collectible — property tests become individually-skipped tests
instead of a module-level collection error.

Usage in test modules:

    from repro.testing import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque stand-in supporting the chaining used at decoration time."""

        def map(self, _fn):
            return self

        def filter(self, _fn):
            return self

        def flatmap(self, _fn):
            return self

    class _St:
        def __getattr__(self, _name):
            return lambda *a, **kw: _Strategy()

    st = _St()

    def settings(*_a, **_kw):
        return lambda fn: fn

    def given(*_a, **_kw):
        import pytest

        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco
