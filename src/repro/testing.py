"""Test-only helpers: property-based testing with or without `hypothesis`.

The property tests use hypothesis when installed. Offline images may not
ship it; importing `given`/`settings`/`st` from here keeps each test module
collectible either way — and, unlike the old skip-stub, the fallback RUNS
the property tests, drawing examples from a seeded generator instead of
skipping them. Shrinking and failure databases are hypothesis luxuries; the
invariants still get exercised on every run, with the failing example's
kwargs in the assertion message for reproduction.

Usage in test modules:

    from repro.testing import given, settings, st

Supported fallback strategies (the subset this repo uses): ``st.integers``,
``st.floats``, ``st.booleans``, ``st.sampled_from``, ``st.lists``,
``st.tuples``, plus ``.map``/``.filter`` chaining. ``@settings`` honors
``max_examples`` (default 20) and ignores the rest.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20
    _FILTER_TRIES = 1000

    class _Strategy:
        """A draw(rng) -> value sampler supporting map/filter chaining."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(_FILTER_TRIES):
                    val = self._draw(rng)
                    if pred(val):
                        return val
                raise RuntimeError("filter predicate too restrictive")
            return _Strategy(draw)

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)).draw(rng))

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[rng.randrange(len(options))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _St()

    def settings(*, max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                # @settings may sit above @given (attribute lands on `run`)
                # or below it (attribute lands on `fn`)
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                # deterministic per-test seed: same examples every run
                rng = random.Random(fn.__name__)
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"property test failed on example {i}: "
                            f"{drawn!r}") from e
            # pytest must see only the NON-drawn parameters (fixtures);
            # the drawn ones are supplied here, not by fixture lookup
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del run.__wrapped__
            return run
        return deco
