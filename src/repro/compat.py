"""Version compatibility for the jax APIs this repo relies on.

The codebase targets the modern jax surface (`jax.make_mesh(axis_types=...)`,
`jax.shard_map`, `pallas.tpu.CompilerParams`); the pinned toolchain may ship
an older jax where those names live elsewhere or take different kwargs. All
version probing lives here so call sites stay on the modern spelling.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """`jax.make_mesh` with explicit-Auto axis types where supported."""
    try:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """`jax.shard_map` (new) or `jax.experimental.shard_map.shard_map` (old).

    `check_vma` (new name) maps onto `check_rep` (old name)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` as a dict — newer jax returns the dict
    directly, older jax wraps it in a one-element list (per device)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def pallas_compiler_params(**kwargs):
    """`pltpu.CompilerParams` (new) / `pltpu.TPUCompilerParams` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


_PIPELINE_PATCHED = False


def ensure_pipeline_emulation() -> None:
    """Make `pltpu.emit_pipeline` runnable in interpret mode off-TPU.

    The mosaic pipeline helper sizes its DMA-slice tiling from the local
    device kind (`assert kind[:5] == "TPU v"`), which trips on the CPU
    backend even though interpret mode emulates the async copies fine. The
    tiling only matters for truncating out-of-bounds edge blocks — our
    kernels require tile-divisible shapes — so pinning a v4-class answer is
    behavior-neutral. No-op on a real TPU backend."""
    global _PIPELINE_PATCHED
    if _PIPELINE_PATCHED or jax.default_backend() == "tpu":
        return
    try:
        from jax._src.pallas.mosaic import pipeline as _pipeline
        _pipeline._get_tpu_generation = lambda: 4
    except (ImportError, AttributeError):  # future jax: probe may be gone
        pass
    _PIPELINE_PATCHED = True
