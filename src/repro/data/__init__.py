from repro.data.synthetic import (lm_batches, transfer_image_batches,
                                  TransferTask)

__all__ = ["lm_batches", "transfer_image_batches", "TransferTask"]
