"""Synthetic data pipelines (no datasets ship offline).

LM: a learnable Markov-ish task — token t+1 = (a·t_k + b·t_{k-1} + noise)
mod V over a random projection table, giving a non-trivial but learnable
next-token distribution (loss decreases well below uniform).

Vision transfer (the paper's CIFAR-from-ImageNet analogue): class-
conditional Gaussian-blob images. The *pretrain* distribution and the
*target* distribution share class structure but differ by a fixed rotation
+ color shift — transfer learning works, and pruning/selection on pretrain
data (the paper's realism requirement) is meaningfully different from the
target data.

Both pipelines are deterministic in (seed, step) so a restarted job
resumes identical batches — part of the fault-tolerance story.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def _lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int,
              table: np.ndarray) -> dict:
    x = np.empty((batch, seq), np.int32)
    x[:, 0] = rng.integers(0, vocab, batch)
    x[:, 1] = rng.integers(0, vocab, batch)
    noise = rng.random((batch, seq)) < 0.05
    rand = rng.integers(0, vocab, (batch, seq))
    for t in range(2, seq):
        nxt = table[x[:, t - 1], x[:, t - 2] % table.shape[1]]
        x[:, t] = np.where(noise[:, t], rand[:, t], nxt)
    return {"tokens": x[:, :-1].copy(), "labels": x[:, 1:].copy()}


def lm_batches(batch: int, seq: int, vocab: int, seed: int = 0,
               start_step: int = 0) -> Iterator[dict]:
    """Deterministic, resumable stream of {"tokens","labels"} ([B, seq])."""
    table_rng = np.random.default_rng(seed)
    table = table_rng.integers(0, vocab, (vocab, min(vocab, 64))).astype(np.int32)
    step = start_step
    while True:
        rng = np.random.default_rng((seed + 1) * 1_000_003 + step)
        yield _lm_batch(rng, batch, seq + 1, vocab, table)
        step += 1


# ---------------------------------------------------------------------------
# vision transfer
# ---------------------------------------------------------------------------

@dataclass
class TransferTask:
    num_classes: int = 10
    img: int = 32
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class prototypes: blob centers + colors, pretrain vs target domain
        self.centers_a = rng.uniform(0.25, 0.75, (self.num_classes, 2))
        self.colors_a = rng.uniform(-1, 1, (self.num_classes, 3))
        rot = np.array([[0, -1], [1, 0]])
        self.centers_b = 0.5 + (self.centers_a - 0.5) @ rot.T
        self.colors_b = np.roll(self.colors_a, 1, axis=1) * 0.9

    def batch(self, n: int, step: int, domain: str = "target") -> dict:
        rng = np.random.default_rng(self.seed * 7 + step * 13 +
                                    (0 if domain == "target" else 1))
        labels = rng.integers(0, self.num_classes, n)
        centers = self.centers_b if domain == "target" else self.centers_a
        colors = self.colors_b if domain == "target" else self.colors_a
        yy, xx = np.mgrid[0:self.img, 0:self.img] / self.img
        imgs = np.empty((n, self.img, self.img, 3), np.float32)
        for i, c in enumerate(labels):
            cy, cx = centers[c] + rng.normal(0, 0.05, 2)
            sigma = 0.12 + rng.normal(0, 0.02)
            r2 = (yy - cy) ** 2 + (xx - cx) ** 2
            if domain == "target":
                # rings instead of filled blobs: low-level feature detectors
                # must adapt, not just the classifier (real transfer)
                shape = np.exp(-((np.sqrt(r2) - 2 * sigma) ** 2) /
                               max(sigma * sigma / 2, 1e-3))
            else:
                shape = np.exp(-(r2 / max(2 * sigma * sigma, 1e-3)))
            img = shape[..., None] * colors[c]
            img = img + rng.normal(0, 0.15, img.shape)
            imgs[i] = img
        return {"images": imgs, "labels": labels.astype(np.int32)}


def transfer_image_batches(batch: int, img: int = 32, seed: int = 0,
                           domain: str = "target",
                           start_step: int = 0) -> Iterator[dict]:
    task = TransferTask(img=img, seed=seed)
    step = start_step
    while True:
        yield task.batch(batch, step, domain)
        step += 1
