"""Deterministic chaos engineering for the serve stack: seeded fault
schedules, injected failures, and simulated crashes.

The paper's premise is surviving hard resource limits — skipped gradient
work, 256KB budgets — and the serve engine inherits the same discipline:
every fallible operation (page allocation, a jitted step, a stream
callback, a checkpoint write) has an *injection point* that consults a
``FaultSchedule``. The schedule is **deterministic**: the n-th draw of a
given fault kind fires iff a counter-keyed hash of ``(seed, kind, n)``
falls under that kind's rate, so the same seed always produces the same
fault sequence regardless of wall time, PYTHONHASHSEED, or platform —
chaos runs are replayable, and CI can pin "5% faults never change served
tokens" as a regression.

Fault kinds (`FaultKind`):

- ``alloc``  — ``PagePool.alloc`` raises ``InjectedFault`` (transient
  allocation failure; the engine retries the slot with backoff).
- ``step``   — a jitted prefill/decode step "fails" BEFORE executing (no
  side effects, so the retry is idempotent by construction).
- ``slow``   — the step runs but takes ``slow_s`` extra seconds (feeds
  the serve-side ``StragglerMonitor``).
- ``stream`` — the per-token stream callback raises (the engine must
  survive a broken client without wedging the slot).
- ``torn``   — a checkpoint write is torn mid-file (the manager publishes
  a truncated file; restore must detect it and fall back).

``poison_rids`` marks specific requests as *poison*: every ``step`` draw
for them fires, so retry alone can never complete them — the quarantine
path (N retries -> request closed as "quarantined", slot freed) is what
keeps one bad request from wedging a slot forever.

``kill_after`` simulates a hard crash: once ``crash_due(n_completed)``
reports True the engine raises ``InjectedCrash`` after its emergency
persist (journal is already fsynced per event), and a restarted engine
replays the request journal through the prefix spill tier.

Zero overhead when disabled: every injection point is gated on
``schedule is not None`` — an engine built without a schedule executes
exactly the pre-chaos code path.

The train-side story (SIGTERM preemption, straggler flagging, restart
loops) lives in ``runtime/fault.py``; this module is its serve-side
counterpart and reuses ``StragglerMonitor`` for per-wave serve timings.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

__all__ = ["FaultKind", "FaultEvent", "FaultSchedule", "InjectedFault",
           "InjectedCrash"]


class InjectedFault(RuntimeError):
    """A transient, injected failure. Carries the fault kind; handlers
    retry (with backoff) or quarantine — never crash."""

    def __init__(self, kind: str, site: Optional[str] = None):
        super().__init__(f"injected {kind} fault"
                         + (f" at {site}" if site else ""))
        self.kind = kind
        self.site = site


class InjectedCrash(BaseException):
    """A simulated hard crash (``kill_after``). Derives from BaseException
    so ordinary ``except Exception`` recovery code cannot accidentally
    swallow it — only the crash-restart harness catches it."""


class FaultKind:
    ALLOC = "alloc"      # page-pool allocation failure
    STEP = "step"        # transient jitted-step error (pre-execution)
    SLOW = "slow"        # slow step (straggler food)
    STREAM = "stream"    # stream-callback exception
    TORN = "torn"        # torn checkpoint write
    ALL = (ALLOC, STEP, SLOW, STREAM, TORN)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fired fault: which kind, the per-kind draw index it fired at,
    and the caller-supplied site tag (a request id, a step label, ...)."""
    kind: str
    index: int
    site: Optional[str] = None


class FaultSchedule:
    """Seeded, deterministic fault source.

    ``draw(kind, site)`` is the single injection primitive: it advances
    the per-kind draw counter and reports whether this draw fires. The
    decision is a pure function of ``(seed, kind, counter)`` — two
    schedules with the same seed and rates produce identical fault
    sequences for identical draw sequences (pinned by a property test).

    ``rates`` maps fault kind -> probability; ``fault_rate`` is the
    shorthand that applies one rate to alloc/step/stream/slow at once.
    """

    def __init__(self, seed: int = 0, *, fault_rate: float = 0.0,
                 rates: Optional[dict] = None, slow_s: float = 0.002,
                 poison_rids: Optional[set] = None,
                 kill_after: Optional[int] = None,
                 max_faults: Optional[int] = None):
        self.seed = int(seed)
        self.rates = {k: float(fault_rate)
                      for k in (FaultKind.ALLOC, FaultKind.STEP,
                                FaultKind.STREAM, FaultKind.SLOW)}
        for k, v in (rates or {}).items():
            assert k in FaultKind.ALL, f"unknown fault kind {k!r}"
            assert 0.0 <= v <= 1.0
            self.rates[k] = float(v)
        self.slow_s = float(slow_s)
        self.poison_rids = set(poison_rids or ())
        self.kill_after = kill_after
        self.max_faults = max_faults
        self._counts: dict[str, int] = {}
        self._crashed = False
        self.events: list[FaultEvent] = []
        self.faults_injected = 0
        self.faults_by_kind: dict[str, int] = {}

    def _uniform(self, kind: str, n: int) -> float:
        """Deterministic draw in [0, 1): counter-keyed crc32, independent
        of call interleaving across kinds (each kind is its own stream)."""
        h = zlib.crc32(f"{self.seed}/{kind}/{n}".encode()) & 0xFFFFFFFF
        return h / 2.0 ** 32

    def draw(self, kind: str, site=None) -> bool:
        """Advance the `kind` stream one draw; True when the fault fires.
        Poison requests ALWAYS fire their step draws (that is what makes
        them poison — retries can never outlast them)."""
        n = self._counts.get(kind, 0)
        self._counts[kind] = n + 1
        if kind == FaultKind.STEP and site is not None \
                and site in self.poison_rids:
            fired = True
        elif self.max_faults is not None \
                and self.faults_injected >= self.max_faults:
            fired = False
        else:
            rate = self.rates.get(kind, 0.0)
            fired = rate > 0.0 and self._uniform(kind, n) < rate
        if fired:
            self.events.append(FaultEvent(kind, n, None if site is None
                                          else str(site)))
            self.faults_injected += 1
            self.faults_by_kind[kind] = self.faults_by_kind.get(kind, 0) + 1
        return fired

    def maybe_raise(self, kind: str, site=None) -> None:
        """``draw`` + raise ``InjectedFault`` when it fires — the one-liner
        for injection points that fail by exception."""
        if self.draw(kind, site):
            raise InjectedFault(kind, None if site is None else str(site))

    def crash_due(self, n_completed: int) -> bool:
        """True exactly once, when `kill_after` completions have been
        reached — the engine raises ``InjectedCrash`` at that point."""
        if self.kill_after is None or self._crashed:
            return False
        if n_completed >= self.kill_after:
            self._crashed = True
            return True
        return False

    def sequence(self) -> list[tuple[str, int, Optional[str]]]:
        """The fired-fault sequence as plain tuples (kind, index, site) —
        the comparison form for the determinism property test."""
        return [(e.kind, e.index, e.site) for e in self.events]
