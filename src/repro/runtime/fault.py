"""Fault-tolerance runtime: preemption handling, straggler detection,
checkpoint-restart loops.

Single-controller implementations with multi-host-shaped interfaces:

- PreemptionHandler: SIGTERM (and, opt-in via include_sigint=True, SIGINT)
  -> grace flag; the train loop checks it each step and performs an
  emergency checkpoint + clean exit (maps to GKE node drain / TPU
  maintenance events). SIGINT stays opt-in so Ctrl-C keeps its normal
  KeyboardInterrupt behavior during interactive runs.
- StragglerMonitor: per-step wall-time watchdog; steps slower than
  `factor` x rolling median are flagged (at pod scale, per-host step times
  are all-gathered and the slow *host* is flagged for replacement — here
  the local step stands in for the host report).
- RestartableLoop: runs a step function under both; resumes from the latest
  checkpoint on (re)start — crash-restart is exercised in tests by killing
  and restarting the loop process.

This module covers the TRAIN loop. The serve-side fault story — seeded
deterministic fault injection (page-alloc failures, transient step errors,
stream-callback exceptions, torn checkpoint writes), request retry with
backoff, poison-request quarantine, load shedding, and the crash-safe
request journal — lives in ``runtime/chaos.py`` and
``serve/journal.py``; the serve engine reuses ``StragglerMonitor`` for
its per-wave step timings.
"""
from __future__ import annotations

import collections
import signal
import statistics
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    """Installs handlers on SIGTERM by default; pass include_sigint=True to
    also trap SIGINT (explicit opt-in — trapping Ctrl-C by default would
    swallow KeyboardInterrupt). Previous handlers are restored on exit."""

    def __init__(self, signals=(signal.SIGTERM,), *,
                 include_sigint: bool = False):
        self._flag = threading.Event()
        self._prev = {}
        sigs = tuple(signals)
        if include_sigint and signal.SIGINT not in sigs:
            sigs += (signal.SIGINT,)
        self._signals = sigs

    def __enter__(self):
        for sig in self._signals:
            self._prev[sig] = signal.signal(sig, self._handle)
        return self

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        return False

    def _handle(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()


class StragglerMonitor:
    """Flags steps (hosts, at scale) slower than factor x rolling median."""

    def __init__(self, factor: float = 2.5, window: int = 32,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.times = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []
        self._step = 0

    def record(self, duration: float) -> bool:
        self._step += 1
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times)
            if duration > self.factor * med:
                is_straggler = True
                self.flagged.append((self._step, duration))
                if self.on_straggler:
                    self.on_straggler(self._step, duration, med)
        self.times.append(duration)
        return is_straggler

    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class RestartableLoop:
    """Checkpointed step loop: resume-from-latest, save-every-N, emergency
    save on preemption, straggler accounting."""

    def __init__(self, manager, state, total_steps: int,
                 checkpoint_every: int = 50,
                 straggler: Optional[StragglerMonitor] = None):
        self.manager = manager
        self.state = state
        self.total_steps = total_steps
        self.checkpoint_every = checkpoint_every
        self.straggler = straggler or StragglerMonitor()
        self.emergency_saved = False

    def resume(self, target=None, shardings=None) -> int:
        step = self.manager.latest_step()
        if step is None:
            return 0
        tree, meta = self.manager.restore(step, target=target or self.state,
                                          shardings=shardings)
        self.state = tree
        return int(meta["step"])

    def run(self, step_fn: Callable, batches, start_step: int = 0,
            on_metrics: Optional[Callable] = None) -> dict:
        with PreemptionHandler() as pre:
            step = start_step
            for batch in batches:
                if step >= self.total_steps:
                    break
                t0 = time.perf_counter()
                self.state, metrics = step_fn(self.state, batch)
                self.straggler.record(time.perf_counter() - t0)
                step += 1
                if on_metrics:
                    on_metrics(step, metrics)
                if pre.preempted:
                    self.manager.save(step, self.state,
                                      {"emergency": True,
                                       "stragglers":
                                           [[int(s), float(d)] for s, d
                                            in self.straggler.flagged],
                                       "median_step_s":
                                           float(self.straggler.median())})
                    self.emergency_saved = True
                    break
                # the final step is saved once, by the `final` save below —
                # saving it here too wrote the same step twice whenever
                # total_steps was a multiple of checkpoint_every
                if step % self.checkpoint_every == 0 \
                        and step != self.total_steps:
                    self.manager.save(step, self.state)
            if step >= self.total_steps:
                self.manager.save(step, self.state, {"final": True})
        return {"state": self.state, "step": step,
                "stragglers": list(self.straggler.flagged),
                "emergency": self.emergency_saved}
