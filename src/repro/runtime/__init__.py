from repro.runtime.fault import PreemptionHandler, StragglerMonitor, RestartableLoop

__all__ = ["PreemptionHandler", "StragglerMonitor", "RestartableLoop"]
