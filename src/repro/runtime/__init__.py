from repro.runtime.chaos import (FaultEvent, FaultKind, FaultSchedule,
                                 InjectedCrash, InjectedFault)
from repro.runtime.fault import (PreemptionHandler, RestartableLoop,
                                 StragglerMonitor)

__all__ = ["PreemptionHandler", "StragglerMonitor", "RestartableLoop",
           "FaultSchedule", "FaultKind", "FaultEvent", "InjectedFault",
           "InjectedCrash"]
