"""Fault-tolerant checkpointing: msgpack + zstd, atomic rename, checksum
manifest, corrupt-file fallback, retention, elastic reshard-on-load.

Format: one `.ckpt` file per save — a zstd-compressed msgpack map of
{ "/"-joined tree path: {dtype, shape, raw bytes} } plus a `__meta__`
entry, followed by an 8-byte checksum footer (crc32 of the compressed
payload + magic). Leaves are stored as *logical* (unsharded) arrays, so a
checkpoint written on one mesh restores onto any other mesh ("elastic"):
the loader device_puts each leaf with the target sharding (or leaves it
on host).

Corruption discipline: a torn or bit-flipped file raises
``CheckpointCorruptError`` (checksum mismatch, missing footer with a
payload that fails to decompress/unpack, ...) instead of an opaque
deserialization error, and ``CheckpointManager.restore`` catches it,
warns, and falls back to the latest *intact* step — a half-written
checkpoint degrades the restore by one save interval, it never crashes
the restart. ``CheckpointManager(chaos=...)`` threads a
``runtime.chaos.FaultSchedule`` through ``save`` so torn writes are
injectable deterministically (fault kind ``torn``).

At real multi-pod scale the same format shards per leaf across processes
(each process writes its addressable shards, `index` entries describe the
slices); the single-controller environment here writes logical arrays
directly. The atomic tmp-file + rename protocol, the checksum manifest,
and the retention policy are the production behaviours that matter for
restart correctness.
"""
from __future__ import annotations

import os
import re
import threading
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # offline images may lack zstd; fall back to zlib
    zstandard = None
import zlib

_CKPT_RE = re.compile(r"step_(\d+)\.ckpt$")
_ZLIB_MAGIC = b"ZLB0"        # our zlib-frame marker (zstd frames start 0x28b52ffd)
_FOOTER_MAGIC = b"RCK1"      # checksum footer: crc32(payload) LE + magic


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed its integrity check (torn write, truncated
    file, bit flip). Restore paths catch this and fall back to the latest
    intact step instead of crashing."""


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return _ZLIB_MAGIC + zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    if blob.startswith(_ZLIB_MAGIC):
        return zlib.decompress(blob[len(_ZLIB_MAGIC):])
    if zstandard is None:
        raise RuntimeError("checkpoint is zstd-compressed but the zstandard "
                           "module is not installed")
    return zstandard.ZstdDecompressor().decompress(blob)


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = leaf
    return flat


def save_pytree(path: str, tree, meta: Optional[dict] = None):
    flat = _flatten(tree)
    payload = {"__meta__": meta or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        payload[key] = {"d": str(arr.dtype), "s": list(arr.shape),
                        "b": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    footer = (zlib.crc32(comp) & 0xFFFFFFFF).to_bytes(4, "little") \
        + _FOOTER_MAGIC
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(comp + footer)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)  # atomic publish


def _read_verified(path: str) -> bytes:
    """Read a checkpoint file and verify its checksum footer. Files written
    before the footer existed are accepted as-is (their decompress/unpack
    stage still catches corruption)."""
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) >= 8 and blob[-4:] == _FOOTER_MAGIC:
        body, crc = blob[:-8], int.from_bytes(blob[-8:-4], "little")
        if (zlib.crc32(body) & 0xFFFFFFFF) != crc:
            raise CheckpointCorruptError(
                f"{path}: checksum mismatch (torn write or bit flip)")
        return body
    return blob


def load_pytree(path: str, target=None, shardings=None):
    """Load a checkpoint. If `target` (a pytree of like-structured arrays or
    ShapeDtypeStructs) is given, the result mirrors its structure; leaves are
    device_put with `shardings` (same-structure tree or None) — this is the
    elastic reshard path. Torn/corrupt files raise
    ``CheckpointCorruptError`` (checksum, decompression, or unpack failure),
    never an opaque deserialization error."""
    body = _read_verified(path)
    if not body:
        raise CheckpointCorruptError(f"{path}: empty checkpoint file "
                                     "(torn write)")
    try:
        raw = _decompress(body)
        payload = msgpack.unpackb(raw, raw=False)
    except RuntimeError:
        raise               # environment problem (e.g. zstd missing), not data
    except Exception as e:
        raise CheckpointCorruptError(
            f"{path}: truncated or corrupt checkpoint ({e})") from e
    if not isinstance(payload, dict):
        raise CheckpointCorruptError(f"{path}: payload is not a map")
    meta = payload.pop("__meta__", {})
    arrays = {}
    for key, rec in payload.items():
        if rec["d"] == "bfloat16":
            arr = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
            arr = jax.lax.bitcast_convert_type(jnp.asarray(arr), jnp.bfloat16)
            arr = np.asarray(jax.device_get(arr))
        else:
            arr = np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])
        arrays[key] = arr

    if target is None:
        return _unflatten_strs(arrays), meta

    flat_t = _flatten(target)
    flat_s = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, tgt in flat_t.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {tgt.shape}")
        val = jnp.asarray(arr, dtype=tgt.dtype)
        if key in flat_s and flat_s[key] is not None:
            val = jax.device_put(val, flat_s[key])
        out[key] = val
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target),
        [out[k] for k in flat_t])
    return tree, meta


def _unflatten_strs(flat: dict[str, Any]):
    root: dict = {}
    for key, val in flat.items():
        node = root
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_delta_store(path: str, store, meta: Optional[dict] = None):
    """Serialize a serve-engine per-user delta store (`repro.serve.deltas.
    DeltaStore` holding `repro.core.delta.DeltaState` entries) into the
    standard .ckpt format: one subtree per resident user, keyed by str(user),
    with the user ids (LRU order, least recent first) recorded in
    meta["delta_users"]. Duck-typed — `store` only needs `users()`/`peek()`
    and entries a `to_tree()`, so this module stays serve-import-free."""
    users = store.users()
    tree = {str(u): store.peek(u).to_tree() for u in users}
    meta = dict(meta or {})
    meta["delta_users"] = [u if isinstance(u, (int, str)) else str(u)
                           for u in users]
    save_pytree(path, tree, meta)


def restore_delta_store(path: str, store):
    """Restore entries written by `save_delta_store` into `store` via its
    `load()` (unpinned, LRU order preserved, capacity bound honored —
    restoring more users than capacity evicts from the least-recent end).
    Returns the checkpoint meta."""
    from repro.core.delta import DeltaState
    arrays, meta = load_pytree(path)
    for user in meta.get("delta_users", sorted(arrays)):
        store.load(user, DeltaState.from_tree(arrays[str(user)]))
    return meta


def save_spill_tier(path: str, tier, meta: Optional[dict] = None):
    """Serialize a serve-engine prefix-cache spill tier (`repro.serve.
    paging.SpillTier`) into the standard .ckpt format: one subtree per
    spilled page boundary — the prefix tokens plus optional device page
    rows and recurrent-state snapshot — with LRU order preserved via
    zero-padded keys recorded in meta["spill_entries"]. Duck-typed (`tier`
    only needs `items()` yielding (tokens, entry) oldest-first), so this
    module stays serve-import-free."""
    tree, order = {}, []
    for i, (tokens, ent) in enumerate(tier.items()):
        key = f"e{i:06d}"
        sub = {"tokens": np.asarray(tokens, np.int32)}
        if ent.get("pages") is not None:
            sub["pages"] = ent["pages"]
        if ent.get("snap") is not None:
            sub["snap"] = ent["snap"]
        tree[key] = sub
        order.append(key)
    meta = dict(meta or {})
    meta["spill_entries"] = order
    save_pytree(path, tree, meta)


def restore_spill_tier(path: str, tier):
    """Restore entries written by `save_spill_tier` into `tier` via its
    `put()` (LRU order preserved, capacity bound honored — restoring more
    entries than capacity drops the least-recent). Returns the meta."""
    arrays, meta = load_pytree(path)
    for key in meta.get("spill_entries", sorted(arrays)):
        ent = arrays[key]
        tier.put(ent["tokens"], pages=ent.get("pages"),
                 snap=ent.get("snap"))
    return meta


class CheckpointManager:
    """save-every-N, keep-last-K manager with atomic writes, checksum
    verification with fall-back-to-intact restore, and latest-checkpoint
    discovery (restart/resume). `chaos` is an optional
    ``runtime.chaos.FaultSchedule``: when its ``torn`` draws fire, `save`
    publishes a deliberately truncated file instead of the real payload —
    the deterministic stand-in for a crash mid-write on a non-atomic
    filesystem, which `restore` must survive."""

    def __init__(self, directory: str, keep: int = 3, chaos=None):
        self.dir = directory
        self.keep = keep
        self.chaos = chaos
        self.torn_writes = 0
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}.ckpt")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _CKPT_RE.search(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree, meta: Optional[dict] = None):
        with self._lock:
            meta = dict(meta or {})
            meta["step"] = int(step)
            meta["time"] = time.time()
            path = self._path(step)
            if self.chaos is not None and self.chaos.draw("torn", site=step):
                # torn publish: write the real bytes, then truncate the
                # published file at half — exactly what a crash mid-write
                # leaves behind on a non-atomic path
                tmp = path + ".chaos"
                save_pytree(tmp, tree, meta)
                with open(tmp, "rb") as f:
                    blob = f.read()
                os.remove(tmp)
                with open(path, "wb") as f:
                    f.write(blob[:max(1, len(blob) // 2)])
                self.torn_writes += 1
            else:
                save_pytree(path, tree, meta)
            self._prune()

    def restore(self, step: Optional[int] = None, target=None, shardings=None):
        """Restore `step` (default: latest). A torn/corrupt file is
        detected (``CheckpointCorruptError``), warned about, and skipped —
        the restore falls back to the latest intact earlier step. Raises
        only when NO intact checkpoint at or below `step` exists."""
        steps = self.all_steps()
        if step is None:
            candidates = list(reversed(steps))
        else:
            candidates = [step] + [s for s in reversed(steps) if s < step]
        if not candidates:
            return None, None
        for s in candidates:
            try:
                return load_pytree(self._path(s), target=target,
                                   shardings=shardings)
            except CheckpointCorruptError as e:
                warnings.warn(f"checkpoint step {s} is torn/corrupt ({e}); "
                              "falling back to the previous intact step")
        raise CheckpointCorruptError(
            f"no intact checkpoint in {self.dir} "
            f"(tried steps {candidates})")

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
