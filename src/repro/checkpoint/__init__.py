from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointManager, load_pytree,
                                      restore_delta_store, restore_spill_tier,
                                      save_delta_store, save_pytree,
                                      save_spill_tier)

__all__ = ["CheckpointCorruptError", "CheckpointManager", "save_pytree",
           "load_pytree", "save_delta_store", "restore_delta_store",
           "save_spill_tier", "restore_spill_tier"]
