from repro.checkpoint.manager import (CheckpointManager, load_pytree,
                                      restore_delta_store, save_delta_store,
                                      save_pytree)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree",
           "save_delta_store", "restore_delta_store"]
