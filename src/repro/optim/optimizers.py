"""Optimizers (built from scratch — no optax in this environment).

Paper-faithful default: SGD momentum 0 (zero optimizer state — the paper's
memory argument), linear warmup + cosine decay. SGD-momentum and AdamW are
provided for the framework; with dynamic channel re-selection their state
for newly-selected channels is implicitly zero, matching the paper's
"reselect and continue" semantics (stale state for deselected channels is
kept but frozen — it receives zero gradients).

Two update entry points share the same per-leaf arithmetic:

- `apply_updates`: the dense sweep — gradients arrive full-shape (zeros
  outside the selection) and every element is updated.
- `apply_updates_mixed`: the compact-gradient path — selectable leaves
  arrive as compact [K, *lead, n_shards, n_sel, block] gradients; the rule
  runs on gathered weight/optimizer-state blocks and the result is
  scatter-written back, so deselected blocks (and their state) are truly
  frozen. See core.sparse_update's module docstring for the equivalence
  guarantees between the two.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.sparse_update import (SelSpec, gather_param_blocks,
                                      kernels_enabled, scatter_param_blocks)


def learning_rate(oc: OptimizerConfig, step) -> jnp.ndarray:
    """Linear warmup then cosine decay (paper §IV-A)."""
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.asarray(oc.learning_rate, jnp.float32)
    if oc.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1.0) / oc.warmup_steps)
    else:
        warm = 1.0
    if oc.decay_steps > 0:
        t = jnp.clip((step - oc.warmup_steps) /
                     max(1, oc.decay_steps - oc.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    else:
        cos = 1.0
    return lr * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.zeros(())
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def init_opt_state(oc: OptimizerConfig, trainable) -> dict:
    if oc.kind == "sgd" and oc.momentum == 0.0:
        return {}                                # paper default: zero state
    if oc.kind in ("sgd", "momentum"):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   trainable)}
    if oc.kind == "adamw":
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"mu": jax.tree.map(z, trainable),
                "nu": jax.tree.map(z, trainable)}
    raise ValueError(oc.kind)


# ---------------------------------------------------------------------------
# per-leaf update rules (shared by the dense sweep and the compact path);
# each returns (new_param_values, new_mu, new_nu) with None for absent state
# ---------------------------------------------------------------------------

def _leaf_update(oc: OptimizerConfig, lr, t, p, g, mu, nu):
    g32 = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    if oc.kind == "sgd" and oc.momentum == 0.0:
        new = p32 - lr * g32
        if oc.weight_decay:
            new = new - lr * oc.weight_decay * p32
        return new.astype(p.dtype), None, None
    if oc.kind in ("sgd", "momentum"):
        mu_new = oc.momentum * mu + g32
        new = p32 - lr * mu_new
        if oc.weight_decay:
            new = new - lr * oc.weight_decay * p32
        return new.astype(p.dtype), mu_new, None
    if oc.kind == "adamw":
        b1, b2 = oc.beta1, oc.beta2
        mu_new = b1 * mu + (1 - b1) * g32
        nu_new = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu_new / (1 - b1 ** t)
        nu_hat = nu_new / (1 - b2 ** t)
        new = p32 - lr * (mu_hat / (jnp.sqrt(nu_hat) + oc.eps)
                          + oc.weight_decay * p32)
        return new.astype(p.dtype), mu_new, nu_new
    raise ValueError(oc.kind)


def apply_updates(oc: OptimizerConfig, params, grads, state: dict, step):
    """Dense sweep: returns (new_params, new_state). Gradients are already
    channel-block sparse (zeros outside the selection) — every element is
    swept, but only selected blocks change (modulo momentum tails and weight
    decay; see core.sparse_update docstring)."""
    lr = learning_rate(oc, step)
    t = jnp.asarray(step, jnp.float32) + 1.0
    grads, _ = clip_by_global_norm(grads, oc.grad_clip)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"]) if "mu" in state \
        else [None] * len(flat_p)
    flat_nu = treedef.flatten_up_to(state["nu"]) if "nu" in state \
        else [None] * len(flat_p)
    out = [_leaf_update(oc, lr, t, p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {}
    if "mu" in state:
        new_state["mu"] = jax.tree_util.tree_unflatten(
            treedef, [o[1] for o in out])
    if "nu" in state:
        new_state["nu"] = jax.tree_util.tree_unflatten(
            treedef, [o[2] for o in out])
    return new_p, new_state


def apply_updates_mixed(oc: OptimizerConfig, params, grads, compact_grads,
                        state: dict, step, sel_idx, spec_tree):
    """Compact-gradient update: selectable leaves (those with a SelSpec in
    `spec_tree`, keyed by segment under params["segments"]) are updated on
    their gathered blocks only — the rule never sweeps the full tensor, and
    optimizer state outside the selection is untouched (frozen). All other
    leaves take the dense rule with their `grads` leaf.

    grads: full-structure dense grads (zero at selectable leaves, from the
    stop-gradient in the compact train step — never read there, so XLA DCEs
    the zeros). compact_grads: {segment: nested {leaf: compact dW}} matching
    `sel_idx`/`spec_tree`. Returns (new_params, new_state).

    Stacked expert leaves ([K, E, d, N] with [K, E, d, n_shards, n_sel,
    block] compact grads, the MoE path) take the same rule: the gather/
    scatter helpers and the fused Pallas kernel treat the extra lead dims
    as rows, so the expert leaf stays one fused launch under
    `use_kernels`."""
    lr = learning_rate(oc, step)
    t = jnp.asarray(step, jnp.float32) + 1.0
    # joint clip: compact leaves hold exactly the nonzero content of their
    # dense counterparts (whose leaves here are zeros), so the global norm
    # matches the dense sweep's up to float-accumulation order
    if oc.grad_clip > 0:
        (grads, compact_grads), _ = clip_by_global_norm(
            (grads, compact_grads), oc.grad_clip)

    def leaf_compact(p, g_sel, idx, spec, mu, nu):
        if kernels_enabled():
            # one in-place Pallas launch: gather + rule + writeback fused,
            # optimizer state updated in the same pass
            from repro.kernels import ops as kops
            return kops.fused_block_optimizer(oc, p, g_sel, idx, spec,
                                              mu, nu, lr, t)
        p_sel = gather_param_blocks(p, idx, spec)
        mu_sel = gather_param_blocks(mu, idx, spec) if mu is not None else None
        nu_sel = gather_param_blocks(nu, idx, spec) if nu is not None else None
        new_sel, mu_new, nu_new = _leaf_update(oc, lr, t, p_sel, g_sel,
                                               mu_sel, nu_sel)
        p_new = scatter_param_blocks(p, new_sel, idx, spec)
        mu_out = scatter_param_blocks(mu, mu_new, idx, spec) \
            if mu is not None else None
        nu_out = scatter_param_blocks(nu, nu_new, idx, spec) \
            if nu is not None else None
        return p_new, mu_out, nu_out

    def walk(p, g, cg, spec, idx, mu, nu):
        if isinstance(spec, SelSpec):
            return leaf_compact(p, cg, idx, spec, mu, nu)
        if isinstance(p, dict):
            out = {}
            for key, sub in p.items():
                in_spec = isinstance(spec, dict) and key in spec
                out[key] = walk(
                    sub, g[key],
                    cg[key] if in_spec and cg is not None else None,
                    spec[key] if in_spec else None,
                    idx[key] if in_spec and idx is not None else None,
                    mu[key] if mu is not None else None,
                    nu[key] if nu is not None else None)
            return out
        return _leaf_update(oc, lr, t, p, g, mu, nu)

    # spec/idx/compact trees are keyed by segment under "segments"
    res = walk(params, grads, {"segments": compact_grads or {}},
               {"segments": spec_tree}, {"segments": sel_idx or {}},
               state.get("mu"), state.get("nu"))

    def pick(node, i):
        if isinstance(node, dict):
            return {k: pick(v, i) for k, v in node.items()}
        return node[i]

    new_p = pick(res, 0)
    new_state = {}
    if "mu" in state:
        new_state["mu"] = pick(res, 1)
    if "nu" in state:
        new_state["nu"] = pick(res, 2)
    return new_p, new_state
