"""Optimizers (built from scratch — no optax in this environment).

Paper-faithful default: SGD momentum 0 (zero optimizer state — the paper's
memory argument), linear warmup + cosine decay. SGD-momentum and AdamW are
provided for the framework; with dynamic channel re-selection their state
for newly-selected channels is implicitly zero, matching the paper's
"reselect and continue" semantics (stale state for deselected channels is
kept but frozen — it receives zero gradients).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def learning_rate(oc: OptimizerConfig, step) -> jnp.ndarray:
    """Linear warmup then cosine decay (paper §IV-A)."""
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.asarray(oc.learning_rate, jnp.float32)
    if oc.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1.0) / oc.warmup_steps)
    else:
        warm = 1.0
    if oc.decay_steps > 0:
        t = jnp.clip((step - oc.warmup_steps) /
                     max(1, oc.decay_steps - oc.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    else:
        cos = 1.0
    return lr * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.zeros(())
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def init_opt_state(oc: OptimizerConfig, trainable) -> dict:
    if oc.kind == "sgd" and oc.momentum == 0.0:
        return {}                                # paper default: zero state
    if oc.kind in ("sgd", "momentum"):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   trainable)}
    if oc.kind == "adamw":
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"mu": jax.tree.map(z, trainable),
                "nu": jax.tree.map(z, trainable)}
    raise ValueError(oc.kind)


def apply_updates(oc: OptimizerConfig, params, grads, state: dict, step):
    """Returns (new_params, new_state). Gradients are already channel-block
    sparse (zeros outside the selection) — updates touch only selected
    blocks."""
    lr = learning_rate(oc, step)
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)

    if oc.kind == "sgd" and oc.momentum == 0.0:
        def upd(p, g):
            new = p.astype(jnp.float32) - lr * g.astype(jnp.float32)
            if oc.weight_decay:
                new = new - lr * oc.weight_decay * p.astype(jnp.float32)
            return new.astype(p.dtype)
        return jax.tree.map(upd, params, grads), state

    if oc.kind in ("sgd", "momentum"):
        def upd(p, g, mu):
            mu_new = oc.momentum * mu + g.astype(jnp.float32)
            new = p.astype(jnp.float32) - lr * mu_new
            if oc.weight_decay:
                new = new - lr * oc.weight_decay * p.astype(jnp.float32)
            return new.astype(p.dtype), mu_new
        out = jax.tree.map(upd, params, grads, state["mu"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_mu}

    if oc.kind == "adamw":
        t = jnp.asarray(step, jnp.float32) + 1.0
        b1, b2 = oc.beta1, oc.beta2

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu_new = b1 * mu + (1 - b1) * g32
            nu_new = b2 * nu + (1 - b2) * g32 * g32
            mu_hat = mu_new / (1 - b1 ** t)
            nu_hat = nu_new / (1 - b2 ** t)
            new = p.astype(jnp.float32) - lr * (
                mu_hat / (jnp.sqrt(nu_hat) + oc.eps)
                + oc.weight_decay * p.astype(jnp.float32))
            return new.astype(p.dtype), mu_new, nu_new
        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        is3 = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is3)
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=is3)
        new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=is3)
        return new_p, {"mu": new_mu, "nu": new_nu}

    raise ValueError(oc.kind)
