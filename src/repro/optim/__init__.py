from repro.optim.optimizers import (init_opt_state, apply_updates,
                                    apply_updates_mixed, learning_rate,
                                    clip_by_global_norm)

__all__ = ["init_opt_state", "apply_updates", "apply_updates_mixed",
           "learning_rate", "clip_by_global_norm"]
