"""Page-granular KV-cache bookkeeping: refcounted page pool + prefix cache.

Pure-python/numpy state (no jax): the engine asks the pool for page ids and
keeps the device-side pools (`models/decoding.py` paged leaves) in sync. A
*page* is `page_size` consecutive token rows of every paged KV leaf; a
request's logical page i lives at physical page `page_table[i]` in every
layer's pool (vLLM-style: one id indexes all layers).

Refcount discipline:

- a live request holds one reference per page in its table;
- the prefix cache holds one reference per registered entry;
- a page with refcount 0 is on the free list. `decref` below zero raises —
  double-frees are bugs, not warnings.

Copy-on-write: writing token rows into a page with refcount > 1 must first
`cow_split` it — allocate a fresh exclusive page, drop one reference on the
shared one — and copy the device rows. The engine triggers this when a
request appends to a page it shares with the prefix cache (or another
request): e.g. the request that *registered* a partially-filled last prompt
page COWs it on its first decode write, leaving the cached page frozen with
prompt-only content.

Prefix sharing is keyed by a rolling crc32 over whole prompt-token pages:
``h_i = crc32(tokens[i*ps:(i+1)*ps], h_{i-1})``. A chain hash therefore
commits to the full token prefix AND its absolute positions, which is what
makes the cached K/V (RoPE'd at absolute positions) reusable. A single
partial-page continuation per chain key is also cached (content-compared on
lookup) so prompts that agree beyond the last full page boundary share it —
that is the page the next appender COW-splits.

Exact-page-multiple edge (fill == 0): such prompts have no partial page to
register, so `match` instead downgrades their cached LAST full page to a
partial (ps-1) match when the >= 1-uncached-token cap — not a hash miss —
stopped the full-page loop. Reading a prefix of a cached page is sound
because pages are absolute-position-addressed; the adopter's first write
into it COW-splits as usual.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from typing import Optional

import numpy as np

__all__ = ["PagePool", "PrefixCache"]


class PagePool:
    """Fixed set of `num_pages` refcounted pages of `page_size` token rows."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.ref = np.zeros((num_pages,), np.int32)
        # LIFO free list: reuse the hottest page first
        self._free = list(range(num_pages - 1, -1, -1))
        self.peak_in_use = 0
        self.cow_splits = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self) -> int:
        """Take a free page (refcount 1). Raises when exhausted — callers
        gate allocations on reservations + cache eviction, so running dry
        here is a bookkeeping bug."""
        if not self._free:
            raise RuntimeError("page pool exhausted (reservation bug)")
        pid = self._free.pop()
        assert self.ref[pid] == 0
        self.ref[pid] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def incref(self, pid: int) -> None:
        assert self.ref[pid] > 0, f"incref of free page {pid}"
        self.ref[pid] += 1

    def decref(self, pid: int) -> None:
        if self.ref[pid] <= 0:
            raise RuntimeError(f"double-free of page {pid}")
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self._free.append(pid)

    def cow_split(self, pid: int) -> int:
        """Resolve a write to shared page `pid`: allocate an exclusive
        replacement and release one reference on the original. The caller
        must copy the device rows pid -> new before writing."""
        assert self.ref[pid] >= 2, f"cow_split of exclusive page {pid}"
        new = self.alloc()
        self.decref(pid)
        self.cow_splits += 1
        return new

    def check(self) -> None:
        """Invariant audit (used by the property tests): every page is
        either free with refcount 0 or in use with refcount > 0, and the
        free list holds no duplicates."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page on free list"
        for pid in range(self.num_pages):
            if pid in free:
                assert self.ref[pid] == 0, f"freed page {pid} still referenced"
            else:
                assert self.ref[pid] > 0, f"leaked page {pid} (ref 0, not free)"


def _page_hash(tokens: np.ndarray, prev: int) -> int:
    return zlib.crc32(np.ascontiguousarray(tokens, np.int32).tobytes(), prev)


class PrefixCache:
    """Chain-hash -> page map for cross-request prompt-prefix sharing.

    Entries hold one pool reference each; `evict_one` drops the oldest entry
    whose page nobody else references (refcount 1), so pinned pages — shared
    with a live request — are never evicted under them.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._full: OrderedDict[int, int] = OrderedDict()       # chain -> pid
        # chain -> (pid, fill, token bytes): one partial continuation per chain
        self._partial: OrderedDict[int, tuple[int, int, bytes]] = OrderedDict()
        self.hit_tokens = 0
        self.lookup_tokens = 0

    def __len__(self) -> int:
        return len(self._full) + len(self._partial)

    def evictable(self) -> int:
        return sum(1 for pid in self._full.values() if self.pool.ref[pid] == 1) \
            + sum(1 for pid, _, _ in self._partial.values()
                  if self.pool.ref[pid] == 1)

    def evict_one(self) -> bool:
        """Drop one unpinned entry (oldest first); True if a page was freed."""
        for table in (self._full, self._partial):
            for key, entry in table.items():
                pid = entry if isinstance(entry, int) else entry[0]
                if self.pool.ref[pid] == 1:
                    del table[key]
                    self.pool.decref(pid)
                    return True
        return False

    def match(self, tokens: np.ndarray, max_tokens: int):
        """Longest cached prefix of `tokens`, capped at `max_tokens` tokens.

        Returns (pages, covered): `pages` is a list of (pid, fill) in logical
        order with one pool reference taken per page (the caller owns them —
        decref on abandon), `covered` the token count they hold. The cap lets
        callers keep >= 1 prompt token uncached (something must produce the
        first sampled token's logits).
        """
        ps = self.pool.page_size
        self.lookup_tokens += len(tokens)
        pages: list[tuple[int, int]] = []
        covered, chain = 0, 0
        while covered + ps <= max_tokens:
            nxt = _page_hash(tokens[covered:covered + ps], chain)
            pid = self._full.get(nxt)
            if pid is None:
                break
            chain = nxt
            self._full.move_to_end(chain)
            self.pool.incref(pid)
            pages.append((pid, ps))
            covered += ps
        part = self._partial.get(chain)
        matched_partial = False
        if part is not None:
            pid, fill, blob = part
            if 0 < fill <= max_tokens - covered and \
                    np.ascontiguousarray(tokens[covered:covered + fill],
                                         np.int32).tobytes() == blob:
                self._partial.move_to_end(chain)
                self.pool.incref(pid)
                pages.append((pid, fill))
                covered += fill
                matched_partial = True
        if not matched_partial and covered + ps == len(tokens) \
                and covered < max_tokens:
            # exact-page-multiple edge: the prompt's LAST page is cached as
            # a full page (its registrant had fill == 0, so no partial entry
            # exists), but the full-page loop above stopped at the >= 1
            # uncached-token cap. Attach that full page as a partial match
            # of its first max_tokens - covered (= ps - 1) rows — absolute
            # positions make the prefix of a cached page freely readable —
            # instead of recomputing a page the cache already holds. Only a
            # complete ps-token slice is ever hashed (hash-only trust, like
            # the loop above).
            nxt = _page_hash(tokens[covered:covered + ps], chain)
            pid = self._full.get(nxt)
            if pid is not None:
                self._full.move_to_end(nxt)
                self.pool.incref(pid)
                pages.append((pid, max_tokens - covered))
                covered = max_tokens
        self.hit_tokens += covered
        return pages, covered

    def abandon(self, pages: list, lookup_tokens: int) -> None:
        """Roll back a `match` whose admission was deferred: release the
        page references AND the hit/lookup counters, so a retried admission
        does not inflate the prefix statistics."""
        for pid, _ in pages:
            self.pool.decref(pid)
        self.hit_tokens -= sum(fill for _, fill in pages)
        self.lookup_tokens -= lookup_tokens

    def match_page(self, tokens: np.ndarray, covered: int) -> Optional[int]:
        """Chunk-time lookup: the single full page at token offset `covered`
        (page-aligned). Lets a request adopt a page that a CONCURRENTLY
        prefilling request registered after this one was admitted — so even
        same-wave admissions of a common prefix share pages. Takes one pool
        reference on a hit."""
        ps = self.pool.page_size
        assert covered % ps == 0
        chain = 0
        for i in range((covered // ps) + 1):
            chain = _page_hash(tokens[i * ps:(i + 1) * ps], chain)
        pid = self._full.get(chain)
        if pid is None:
            return None
        self._full.move_to_end(chain)
        self.pool.incref(pid)
        self.hit_tokens += ps
        return pid

    def register_full(self, tokens: np.ndarray, upto_page: int,
                      page_ids: list[int], registered: int) -> int:
        """Register full prompt pages [registered, upto_page) of a request
        (token content final — chunked prefill has written their K/V).
        Returns the new `registered` watermark."""
        ps = self.pool.page_size
        chain = 0
        for i in range(upto_page):
            chain = _page_hash(tokens[i * ps:(i + 1) * ps], chain)
            if i < registered:
                continue
            if chain not in self._full:
                self._full[chain] = page_ids[i]
                self.pool.incref(page_ids[i])
        return max(registered, upto_page)

    def register_partial(self, tokens: np.ndarray, pid: int) -> bool:
        """Register the final, partially-filled prompt page (fill = len %
        page_size tokens). The owner COWs it on its next write, freezing the
        cached copy at prompt-only content."""
        ps = self.pool.page_size
        fill = len(tokens) % ps
        if fill == 0:
            return False
        chain = 0
        for i in range(len(tokens) // ps):
            chain = _page_hash(tokens[i * ps:(i + 1) * ps], chain)
        if chain in self._partial:
            return False
        blob = np.ascontiguousarray(tokens[-fill:], np.int32).tobytes()
        self._partial[chain] = (pid, fill, blob)
        self.pool.incref(pid)
        return True
