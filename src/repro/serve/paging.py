"""Page-granular KV-cache bookkeeping: refcounted page pool, radix-tree
prefix reuse, and a host-memory spill tier.

Pure-python/numpy state (no jax): the engine asks the pool for page ids and
keeps the device-side pools (`models/decoding.py` paged leaves) in sync. A
*page* is `page_size` consecutive token rows of every paged KV leaf; a
request's logical page i lives at physical page `page_table[i]` in every
layer's pool (vLLM-style: one id indexes all layers).

Refcount discipline:

- a live request holds one reference per page in its table;
- the prefix cache holds one reference per page owned by a tree node;
- a page with refcount 0 is on the free list. `decref` below zero raises —
  double-frees are bugs, not warnings.

Copy-on-write: writing token rows into a page with refcount > 1 must first
`cow_split` it — allocate a fresh exclusive page, drop one reference on the
shared one — and copy the device rows. The engine triggers this when a
request appends to a page it shares with the prefix cache (or another
request): e.g. the request that *registered* a partially-filled last prompt
page COWs it on its first decode write, leaving the cached page frozen with
prompt-only content.

Radix lifecycle (SGLang's RadixAttention discipline, over token pages)
----------------------------------------------------------------------
``RadixPrefixCache`` keys reuse by token *content*: tree edges are runs of
whole token pages (children keyed by their first page's token bytes), so a
lookup walks arbitrary shared prefixes — not just whole registered chains —
and diverging prompts share every page up to their split point.

    insert    — ``insert_pages`` descends the tree, SPLITTING a node at the
                page boundary where the new prompt diverges, then hangs the
                uncovered pages off the split point (one pool reference per
                page). ``insert_snapshot`` attaches a recurrent-state blob
                (ring k/v, mamba h+conv, rwkv S+last — see
                ``models/decoding.py`` CacheFamily) to the node ending at a
                page boundary, so state families join prefix sharing; for
                page-less archs (rwkv) the nodes carry no pages at all.
    match     — walks the longest page-aligned shared prefix under the
                caller's cap, increfs every matched page, and — for state
                families — clamps coverage to the deepest snapshot
                boundary, returning the blob to restore. At most one
                partial-page continuation (content-compared) or an
                exact-page-multiple downgrade extends the match.
    pin       — every match pins its deepest node (`pins` count); pinned
                nodes and nodes whose pages a live slot still references
                (refcount > 1) are never evicted.
    evict     — an explicit unpinned-leaf LRU: every touch pushes a
                (stamp, node) entry on a lazy-invalidation heap, so
                ``evict_one`` is O(depth) amortized instead of the old
                O(n) scan over both chain tables. Evicting a leaf may
                promote its parent to a leaf (pushed back on the heap).
    spill     — evicted full-page nodes write their device page rows
                (via the engine's reader callback) and snapshot blob into
                the host ``SpillTier`` keyed by the full token prefix, an
                O(1) LRU writeback queue. Partial pages are dropped, not
                spilled (their content is not page-aligned addressable).
    rehydrate — a match that misses in the tree consults the spill tier:
                a hit allocates a free page, writes the saved rows back
                into the device pools (writer callback), and re-attaches
                the node — so a restarted engine (or a later ``run()``)
                serves its system-prompt tree instead of starting cold.
                ``checkpoint/manager.py`` serializes the tier to disk for
                ``--prefix-persist``.

``ChainPrefixCache`` keeps the previous whole-chain rolling-crc32 design as
the comparison baseline (`prefix_mode="chain"`): one partial continuation
per chain, no snapshots, no spill, fully-paged archs only.
"""
from __future__ import annotations

import dataclasses
import heapq
import zlib
from collections import OrderedDict
from typing import Any, Callable, Optional

import numpy as np

from repro.runtime.chaos import FaultKind, InjectedFault

__all__ = ["PagePool", "RadixPrefixCache", "ChainPrefixCache", "SpillTier",
           "MatchResult"]


class PagePool:
    """Fixed set of `num_pages` refcounted pages of `page_size` token rows.

    `chaos` is an optional ``runtime.chaos.FaultSchedule``: when set, its
    ``alloc`` draws make `alloc` raise ``InjectedFault`` BEFORE any state
    changes — the deterministic stand-in for a transient allocation
    failure, which callers (the engine's retry path) must absorb without
    corrupting the refcount discipline."""

    def __init__(self, num_pages: int, page_size: int, chaos=None):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.chaos = chaos
        self.ref = np.zeros((num_pages,), np.int32)
        # LIFO free list: reuse the hottest page first
        self._free = list(range(num_pages - 1, -1, -1))
        self.peak_in_use = 0
        self.cow_splits = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self) -> int:
        """Take a free page (refcount 1). Raises when exhausted — callers
        gate allocations on reservations + cache eviction, so running dry
        here is a bookkeeping bug. An injected ``alloc`` fault raises
        before any mutation, so a caught fault leaves the pool intact."""
        if self.chaos is not None:
            self.chaos.maybe_raise(FaultKind.ALLOC)
        if not self._free:
            raise RuntimeError("page pool exhausted (reservation bug)")
        pid = self._free.pop()
        assert self.ref[pid] == 0
        self.ref[pid] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def incref(self, pid: int) -> None:
        assert self.ref[pid] > 0, f"incref of free page {pid}"
        self.ref[pid] += 1

    def decref(self, pid: int) -> None:
        if self.ref[pid] <= 0:
            raise RuntimeError(f"double-free of page {pid}")
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self._free.append(pid)

    def cow_split(self, pid: int) -> int:
        """Resolve a write to shared page `pid`: allocate an exclusive
        replacement and release one reference on the original. The caller
        must copy the device rows pid -> new before writing."""
        assert self.ref[pid] >= 2, f"cow_split of exclusive page {pid}"
        new = self.alloc()
        self.decref(pid)
        self.cow_splits += 1
        return new

    def check(self) -> None:
        """Invariant audit (used by the property tests): every page is
        either free with refcount 0 or in use with refcount > 0, and the
        free list holds no duplicates."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page on free list"
        for pid in range(self.num_pages):
            if pid in free:
                assert self.ref[pid] == 0, f"freed page {pid} still referenced"
            else:
                assert self.ref[pid] > 0, f"leaked page {pid} (ref 0, not free)"


def _as_tokens(tokens) -> np.ndarray:
    return np.ascontiguousarray(tokens, np.int32)


def _tree_nbytes(tree) -> int:
    if tree is None:
        return 0
    if isinstance(tree, dict):
        return sum(_tree_nbytes(v) for v in tree.values())
    return int(np.asarray(tree).nbytes)


@dataclasses.dataclass
class MatchResult:
    """One prefix-cache lookup. `pages` is a list of (pid, fill) in logical
    order with one pool reference taken per page (the caller owns them —
    `abandon` rolls everything back), `covered` the token count they (plus
    any snapshot) hold, `snapshot` the host recurrent-state blob to restore
    at token `covered` (state families only). The deepest node stays pinned
    until `release` (slot close) or `abandon` (admission rollback)."""
    pages: list
    covered: int
    snapshot: Any = None
    node: Any = None        # pinned tree node (None for the chain baseline)
    state: bool = False     # lookup asked for a snapshot (state family)


class SpillTier:
    """Host-memory spill target for evicted radix nodes: an O(1) LRU
    writeback queue (OrderedDict move_to_end/popitem — same discipline as
    the tree's unpinned-leaf LRU) of per-page-boundary entries keyed by the
    full token prefix. Each entry holds the device page rows (host numpy
    tree) and/or the recurrent-state snapshot at that boundary. The engine
    owns ONE tier across `run()` calls, and `checkpoint/manager.py`
    serializes it for `--prefix-persist`."""

    def __init__(self, max_entries: int = 4096):
        assert max_entries >= 1
        self.max_entries = max_entries
        self._entries: OrderedDict[bytes, dict] = OrderedDict()
        self.puts = 0
        self.takes = 0
        self.evicted = 0        # entries dropped off the writeback queue

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, prefix_tokens, *, pages=None, snap=None) -> None:
        """Merge (pages, snap) into the entry for this token prefix and mark
        it most-recently-written; oldest entries fall off the queue."""
        if pages is None and snap is None:
            return
        toks = _as_tokens(prefix_tokens)
        key = toks.tobytes()
        ent = self._entries.get(key)
        if ent is None:
            ent = {"tokens": toks.copy()}
            self._entries[key] = ent
        if pages is not None:
            ent["pages"] = pages
        if snap is not None:
            ent["snap"] = snap
        self._entries.move_to_end(key)
        self.puts += 1
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evicted += 1

    def peek(self, prefix_tokens) -> Optional[dict]:
        return self._entries.get(_as_tokens(prefix_tokens).tobytes())

    def take(self, prefix_tokens) -> Optional[dict]:
        ent = self._entries.pop(_as_tokens(prefix_tokens).tobytes(), None)
        if ent is not None:
            self.takes += 1
        return ent

    def items(self):
        """(tokens, entry) in LRU order, oldest first — the serialization
        hook for `checkpoint.manager.save_spill_tier` (duck-typed so the
        checkpoint module stays serve-import-free)."""
        for ent in self._entries.values():
            yield ent["tokens"], ent

    def clear(self) -> None:
        self._entries.clear()


class _Node:
    """One radix edge: a run of whole token pages (or a sub-page partial
    continuation). `snapshot` is the recurrent state at the node's END
    boundary; splits keep it on the bottom half, so that stays true."""
    __slots__ = ("key", "pages", "parent", "children", "partials",
                 "snapshot", "pins", "stamp", "partial")

    def __init__(self, key: np.ndarray, parent: Optional["_Node"],
                 pages: Optional[list] = None, partial: bool = False):
        self.key = key                  # np.int32 tokens this edge covers
        self.pages = pages              # page ids (None for page-less archs)
        self.parent = parent
        self.children: dict[bytes, _Node] = {}
        self.partials: list[_Node] = []
        self.snapshot = None
        self.pins = 0
        self.stamp = 0
        self.partial = partial


class RadixPrefixCache:
    """Radix tree over token pages for cross-request prefix reuse (see the
    module docstring for the full insert/match/pin/evict/spill/rehydrate
    lifecycle). `reader(pid) -> host tree` / `writer(pid, host tree)` are
    the engine callbacks that move device page rows to/from the spill tier;
    `has_pages=False` serves page-less (pure recurrent-state) archs, whose
    nodes carry snapshots only."""

    def __init__(self, pool: PagePool, *, has_pages: bool = True,
                 reader: Optional[Callable[[int], Any]] = None,
                 writer: Optional[Callable[[int, Any], None]] = None,
                 spill: Optional[SpillTier] = None,
                 snapshot_budget: int = 256, max_nodes: int = 4096,
                 partial_slots: int = 2):
        assert snapshot_budget >= 1 and max_nodes >= 2 and partial_slots >= 1
        self.pool = pool
        self.has_pages = has_pages
        self.spill = spill
        self._reader = reader
        self._writer = writer
        self._ps = pool.page_size
        self._root = _Node(np.zeros((0,), np.int32), None)
        self._lru: list = []            # (stamp, seq, node) lazy-invalidation heap
        self._clock = 0
        self._nodes = 0                 # non-root node count
        self._snaps: OrderedDict[int, _Node] = OrderedDict()  # id(node) -> node
        self.snapshot_budget = snapshot_budget
        self.max_nodes = max_nodes
        self.partial_slots = partial_slots
        # statistics
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.lookups = 0
        self.state_lookups = 0      # lookups with need_state (state family)
        self.snapshot_hits = 0
        self.snapshots_stored = 0
        self.snapshot_bytes = 0
        self.spills = 0
        self.rehydrates = 0

    def __len__(self) -> int:
        return self._nodes

    @property
    def node_count(self) -> int:
        return self._nodes

    # -- LRU plumbing ------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock
        heapq.heappush(self._lru, (node.stamp, self._clock, node))

    def _push(self, node: _Node) -> None:
        """Re-announce `node` on the heap WITHOUT refreshing its recency
        (used when an eviction turns it into a leaf, or when a blocked
        entry is put back)."""
        self._clock += 1
        heapq.heappush(self._lru, (node.stamp, self._clock, node))

    # -- tree walking ------------------------------------------------------

    def _match_pages(self, child: _Node, tokens: np.ndarray, covered: int,
                     limit: int) -> int:
        """Tokens of `child.key` matching tokens[covered:], whole pages
        only, capped at `limit` tokens (rounded down to a page)."""
        ps = self._ps
        k = min(len(child.key), limit - (limit % ps))
        m = 0
        while m < k and child.key[m:m + ps].tobytes() == \
                tokens[covered + m:covered + m + ps].tobytes():
            m += ps
        return m

    def _split(self, node: _Node, at: int) -> _Node:
        """Split a full node at token offset `at` (page multiple, interior).
        The TOP half takes the first pages and replaces `node` under its
        parent; `node` keeps the tail — and its snapshot (END boundary),
        partials, and pins, which all describe the original end."""
        ps = self._ps
        assert 0 < at < len(node.key) and at % ps == 0 and not node.partial
        top = _Node(node.key[:at].copy(), node.parent,
                    pages=(node.pages[:at // ps]
                           if node.pages is not None else None))
        node.parent.children[top.key[:ps].tobytes()] = top
        node.key = node.key[at:].copy()
        if node.pages is not None:
            node.pages = node.pages[at // ps:]
        node.parent = top
        top.children[node.key[:ps].tobytes()] = node
        top.stamp = node.stamp
        self._nodes += 1
        self._push(top)
        return top

    def _descend(self, tokens: np.ndarray, target: int):
        """Walk full-page edges toward token `target` (page multiple),
        splitting at divergence/cap points so the returned node ends
        exactly at the deepest matched page boundary <= target.
        Returns (node, covered)."""
        ps = self._ps
        node, covered = self._root, 0
        while covered < target:
            child = node.children.get(tokens[covered:covered + ps].tobytes())
            if child is None:
                break
            m = self._match_pages(child, tokens, covered, target - covered)
            if m == 0:
                break
            if m < len(child.key):
                child = self._split(child, m)
            node = child
            covered += m
            self._touch(node)
        return node, covered

    def _locate(self, tokens: np.ndarray, boundary: int):
        """No-split read-only walk to token `boundary` (page multiple).
        Returns (node, off) with `boundary == node_start + off` (off ==
        len(node.key) means exactly the node end), or None when the tree
        does not cover [0, boundary)."""
        node, c = self._root, 0
        while c < boundary:
            child = node.children.get(tokens[c:c + self._ps].tobytes())
            if child is None:
                return None
            m = self._match_pages(child, tokens, c, boundary - c)
            if m == 0:
                return None
            node = child
            c += m
            if m < len(child.key):
                return (node, m) if c == boundary else None
        return node, (len(node.key) if node is not self._root else 0)

    def _prefix_of(self, node: _Node) -> np.ndarray:
        parts = []
        n = node
        while n is not None:
            parts.append(n.key)
            n = n.parent
        return np.concatenate(list(reversed(parts)))

    # -- match -------------------------------------------------------------

    def match(self, tokens, max_tokens: int, *,
              need_state: bool = False) -> MatchResult:
        """Longest cached prefix of `tokens`, capped at `max_tokens` tokens
        (callers keep >= 1 prompt token uncached: something must produce the
        first sampled token's logits). With `need_state`, coverage is
        clamped to the deepest snapshot boundary — pages beyond it are
        useless without the recurrent state that accompanies them."""
        ps = self._ps
        tokens = _as_tokens(tokens)
        self.lookup_tokens += len(tokens)
        self.lookups += 1
        if need_state:
            # snapshot_hit_rate denominates by these, not all lookups:
            # attention-family traffic never asks for snapshots
            self.state_lookups += 1
        node, covered = self._root, 0
        pages: list[int] = []
        snap_node, snap_at = None, 0
        at_end, off_last = True, 0
        while covered + ps <= max_tokens:
            key = tokens[covered:covered + ps].tobytes()
            child = node.children.get(key)
            if child is None and self.spill is not None:
                child = self._rehydrate(node, tokens, covered)
            if child is None:
                break
            m = self._match_pages(child, tokens, covered, max_tokens - covered)
            if m == 0:
                break
            if child.pages is not None:
                pages.extend(child.pages[:m // ps])
            covered += m
            self._touch(child)
            node = child
            if m < len(child.key):
                at_end, off_last = False, m
                break
            if child.snapshot is not None:
                snap_node, snap_at = child, covered

        snapshot, pin = None, (node if covered else None)
        out: list[tuple[int, int]] = [(pid, ps) for pid in pages]
        if need_state:
            # only state-accompanied coverage is usable: clamp to the
            # deepest snapshot boundary and drop the pages beyond it
            covered = snap_at
            out = out[:snap_at // ps]
            pin = snap_node
            if snap_node is not None:
                snapshot = snap_node.snapshot
                self.snapshot_hits += 1
                self._snaps.move_to_end(id(snap_node))
        else:
            matched_partial = False
            if at_end:
                best = None
                for pn in node.partials:
                    fill = len(pn.key)
                    if 0 < fill <= max_tokens - covered and \
                            (best is None or fill > len(best.key)) and \
                            pn.key.tobytes() == \
                            tokens[covered:covered + fill].tobytes():
                        best = pn
                if best is not None:
                    self._touch(best)
                    out.append((best.pages[0], len(best.key)))
                    covered += len(best.key)
                    pin, matched_partial = best, True
            if not matched_partial and covered + ps == len(tokens) \
                    and covered < max_tokens:
                # exact-page-multiple edge: the prompt's LAST page is cached
                # as a full page (its registrant had fill == 0, so no partial
                # node exists), but the loop above stopped at the >= 1
                # uncached-token cap. Attach that page as a partial match of
                # its first max_tokens - covered rows — absolute positions
                # make the prefix of a cached page freely readable.
                want = tokens[covered:covered + ps].tobytes()
                down = None
                if at_end:
                    nxt = node.children.get(want)
                    if nxt is None and self.spill is not None:
                        nxt = self._rehydrate(node, tokens, covered)
                    if nxt is not None and nxt.pages is not None:
                        self._touch(nxt)
                        down = nxt.pages[0]
                        pin = nxt
                elif node.pages is not None and \
                        node.key[off_last:off_last + ps].tobytes() == want:
                    down = node.pages[off_last // ps]
                if down is not None:
                    out.append((down, max_tokens - covered))
                    covered = max_tokens

        for pid, _ in out:
            self.pool.incref(pid)
        if pin is not None:
            pin.pins += 1
        self.hit_tokens += covered
        return MatchResult(pages=out, covered=covered, snapshot=snapshot,
                           node=pin, state=need_state)

    def abandon(self, mr: MatchResult, lookup_tokens: int) -> None:
        """Roll back a `match` whose admission was deferred: release the
        page references, the pin, AND the hit/lookup counters, so a retried
        admission does not inflate the prefix statistics."""
        for pid, _ in mr.pages:
            self.pool.decref(pid)
        self.hit_tokens -= mr.covered
        self.lookup_tokens -= lookup_tokens
        self.lookups -= 1
        if mr.state:
            self.state_lookups -= 1
        if mr.snapshot is not None:
            self.snapshot_hits -= 1
        self.release(mr)

    def release(self, mr: MatchResult) -> None:
        """Unpin the match's node (slot closed / admission rolled back)."""
        if mr.node is not None:
            assert mr.node.pins > 0
            mr.node.pins -= 1
            mr.node = None

    def match_page(self, tokens, covered: int) -> Optional[int]:
        """Chunk-time lookup: the single full page at token offset `covered`
        (page-aligned). Lets a request adopt a page that a CONCURRENTLY
        prefilling request registered after this one was admitted — so even
        same-wave admissions of a common prefix share pages. Takes one pool
        reference on a hit."""
        ps = self._ps
        assert covered % ps == 0
        tokens = _as_tokens(tokens)
        loc = self._locate(tokens, covered)
        if loc is None:
            return None
        node, off = loc
        want = tokens[covered:covered + ps].tobytes()
        if node is not self._root and off < len(node.key):
            if node.pages is None or \
                    node.key[off:off + ps].tobytes() != want:
                return None
            pid = node.pages[off // ps]
            self._touch(node)
        else:
            child = node.children.get(want)
            if child is None or child.pages is None:
                return None
            pid = child.pages[0]
            self._touch(child)
        self.pool.incref(pid)
        self.hit_tokens += ps
        return pid

    # -- insert ------------------------------------------------------------

    def insert_pages(self, tokens, upto_page: int, page_ids: list,
                     registered: int) -> int:
        """Register full prompt pages [0, upto_page) of a request (token
        content final — chunked prefill has written their K/V); pages the
        tree already holds are skipped, the rest hang off the divergence
        point as one new node. Returns the new `registered` watermark."""
        ps = self._ps
        target = upto_page * ps
        tokens = _as_tokens(tokens)[:target]
        node, covered = self._descend(tokens, target)
        if covered < target:
            pages = None
            if self.has_pages:
                pages = [int(p) for p in page_ids[covered // ps:upto_page]]
                for pid in pages:
                    self.pool.incref(pid)
            child = _Node(tokens[covered:target].copy(), node, pages=pages)
            node.children[child.key[:ps].tobytes()] = child
            self._nodes += 1
            self._touch(child)
            self._maybe_evict_nodes()
        return max(registered, upto_page)

    def insert_partial(self, tokens, pid: int) -> bool:
        """Register the final, partially-filled prompt page (fill = len %
        page_size tokens) as a partial leaf under the node ending at the
        last full-page boundary. Unlike the chain baseline's one-per-chain
        slot, content-distinct continuations coexist — up to
        `partial_slots` per spine, LRU-displaced beyond that so the tree
        never hoards one speculative page per historical request (peak
        page usage stays BELOW the no-sharing run's). The owner COWs the
        page on its next write, freezing the cached copy at prompt-only
        content."""
        ps = self._ps
        tokens = _as_tokens(tokens)
        fill = len(tokens) % ps
        if fill == 0 or not self.has_pages:
            return False
        boundary = len(tokens) - fill
        node, covered = self._descend(tokens, boundary)
        if covered < boundary:
            return False        # full-page spine was evicted under us
        tail = tokens[boundary:]
        for pn in node.partials:
            if np.array_equal(pn.key, tail):
                return False
        while len(node.partials) >= self.partial_slots:
            live = [p for p in node.partials if p.pins == 0]
            if not live:
                return False    # every slot pinned by a live match
            self._drop_leaf(min(live, key=lambda p: p.stamp))
        pn = _Node(tail.copy(), node, pages=[int(pid)], partial=True)
        node.partials.append(pn)
        self.pool.incref(pid)
        self._nodes += 1
        self._touch(pn)
        self._maybe_evict_nodes()
        return True

    def wants_snapshot(self, tokens, boundary: int) -> bool:
        """True when no snapshot exists at this page boundary yet — the
        engine skips the device->host state extraction otherwise."""
        if boundary <= 0 or boundary % self._ps:
            return False
        loc = self._locate(_as_tokens(tokens), boundary)
        if loc is None:
            return True
        node, off = loc
        if node is self._root or off < len(node.key):
            return True         # boundary mid-node: no snapshot AT it
        return node.snapshot is None

    def insert_snapshot(self, tokens, boundary: int, blob) -> bool:
        """Attach the recurrent-state blob at token `boundary` (page
        multiple) to the node ending there, splitting a longer edge when
        needed; page-less archs grow snapshot-only nodes. First write wins
        (identical prefixes produce identical state)."""
        ps = self._ps
        assert boundary > 0 and boundary % ps == 0
        tokens = _as_tokens(tokens)[:boundary]
        node, covered = self._descend(tokens, boundary)
        if covered < boundary:
            if self.has_pages:
                return False    # page spine evicted under us: no holes
            child = _Node(tokens[covered:boundary].copy(), node, pages=None)
            node.children[child.key[:ps].tobytes()] = child
            self._nodes += 1
            self._touch(child)
            node = child
        if node.snapshot is None:
            node.snapshot = blob
            self._snaps[id(node)] = node
            self.snapshots_stored += 1
            self.snapshot_bytes += _tree_nbytes(blob)
            self._enforce_snapshot_budget()
        self._maybe_evict_nodes()
        return True

    # -- evict / spill / rehydrate ----------------------------------------

    def evictable(self) -> int:
        """Pages the cache could free under leaf-first eviction right now
        (pinned nodes and pages shared with live slots block themselves AND
        their ancestors). With no live slots this is every cached page —
        the property `_headroom` relies on for deadlock-free admission."""
        def rec(node):
            pages, all_gone = 0, True
            for ch in node.children.values():
                p, g = rec(ch)
                pages += p
                all_gone = all_gone and g
            for pn in node.partials:
                if pn.pins == 0 and self.pool.ref[pn.pages[0]] == 1:
                    pages += 1
                else:
                    all_gone = False
            if node is self._root:
                return pages, all_gone
            own = node.pages or []
            if all_gone and node.pins == 0 and \
                    all(self.pool.ref[p] == 1 for p in own):
                return pages + len(own), True
            return pages, False
        return rec(self._root)[0]

    def evict_one(self) -> bool:
        """Drop the least-recently-touched unpinned leaf (O(depth)
        amortized: the heap is lazily invalidated, blocked entries keep
        their recency). True when a node was evicted."""
        blocked = []
        evicted = False
        while self._lru:
            stamp, _, node = heapq.heappop(self._lru)
            if node.stamp != stamp or node.parent is None:
                continue                    # stale entry or detached node
            if node.children or node.partials:
                continue                    # re-pushed when it becomes a leaf
            if node.pins > 0 or (node.pages and
                                 any(self.pool.ref[p] > 1
                                     for p in node.pages)):
                blocked.append(node)        # pinned by a match or a live slot
                continue
            self._drop_leaf(node)
            evicted = True
            break
        for node in blocked:
            self._push(node)
        return evicted

    def _drop_leaf(self, node: _Node) -> None:
        if self.spill is not None and not node.partial:
            self._spill_node(node)
        if node.pages:
            for pid in node.pages:
                self.pool.decref(pid)
        parent = node.parent
        if node.partial:
            parent.partials.remove(node)
        else:
            del parent.children[node.key[:self._ps].tobytes()]
        if node.snapshot is not None:
            self._snaps.pop(id(node), None)
            self.snapshot_bytes -= _tree_nbytes(node.snapshot)
            node.snapshot = None
        node.parent = None
        node.stamp = -1
        self._nodes -= 1
        if parent is not self._root and not parent.children \
                and not parent.partials:
            self._push(parent)              # parent became an evictable leaf

    def _maybe_evict_nodes(self) -> None:
        while self._nodes > self.max_nodes:
            if not self.evict_one():
                break

    def _enforce_snapshot_budget(self) -> None:
        while len(self._snaps) > self.snapshot_budget:
            _, node = self._snaps.popitem(last=False)
            if self.spill is not None:
                self.spill.put(self._prefix_of(node), snap=node.snapshot)
                self.spills += 1
            self.snapshot_bytes -= _tree_nbytes(node.snapshot)
            node.snapshot = None            # node (and its pages) stay

    def _spill_node(self, node: _Node) -> None:
        """Write a full-page node's device rows + end-boundary snapshot into
        the spill tier, one entry per page boundary."""
        prefix = self._prefix_of(node)
        start = len(prefix) - len(node.key)
        ps = self._ps
        n_pages = len(node.key) // ps
        for i in range(n_pages):
            end = start + (i + 1) * ps
            page_blob = None
            if node.pages is not None and self._reader is not None:
                page_blob = self._reader(node.pages[i])
            snap = node.snapshot if i == n_pages - 1 else None
            if page_blob is None and snap is None:
                continue
            self.spill.put(prefix[:end], pages=page_blob, snap=snap)
            self.spills += 1

    def _rehydrate(self, node: _Node, tokens: np.ndarray,
                   covered: int) -> Optional[_Node]:
        """Re-attach one spilled page boundary as a child of `node` during a
        match walk: allocate a FREE page (no eviction cascades mid-match)
        and write the saved rows back into the device pools."""
        ps = self._ps
        key = tokens[:covered + ps]
        ent = self.spill.peek(key)
        if ent is None:
            return None
        pages = None
        if self.has_pages:
            blob = ent.get("pages")
            if blob is None or self._writer is None or \
                    self.pool.free_pages == 0:
                return None
            try:
                pid = self.pool.alloc()
            except InjectedFault:
                return None     # rehydration is opportunistic: a transient
                                # alloc fault degrades to a cache miss
            self._writer(pid, blob)
            pages = [pid]
        elif ent.get("snap") is None:
            return None
        self.spill.take(key)
        child = _Node(tokens[covered:covered + ps].copy(), node, pages=pages)
        node.children[child.key.tobytes()] = child
        snap = ent.get("snap")
        if snap is not None:
            child.snapshot = snap
            self._snaps[id(child)] = child
            self.snapshot_bytes += _tree_nbytes(snap)
        self._nodes += 1
        self._touch(child)
        self.rehydrates += 1
        self._enforce_snapshot_budget()
        return child

    def spill_all(self) -> None:
        """Write every full-page node (pages + snapshots) into the spill
        tier WITHOUT evicting — the end-of-run hook that lets the next
        `run()` (or a restarted engine via `--prefix-persist`) rehydrate
        instead of starting cold. Partial pages are dropped by design."""
        if self.spill is None:
            return
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self._spill_node(n)

    # -- invariants (tests) ------------------------------------------------

    def check(self) -> None:
        """Structural audit: parent/child links, page ownership (each page
        owned by exactly one node, one pool ref each), page-aligned keys."""
        seen: list[int] = []
        count = 0

        def rec(node):
            nonlocal count
            if node is not self._root:
                count += 1
                assert node.parent is not None
                if node.partial:
                    assert 0 < len(node.key) < self._ps
                    assert self.has_pages and len(node.pages) == 1
                else:
                    assert len(node.key) > 0 and len(node.key) % self._ps == 0
                    if self.has_pages:
                        assert node.pages is not None and \
                            len(node.pages) == len(node.key) // self._ps
                if node.pages:
                    seen.extend(node.pages)
                    for p in node.pages:
                        assert self.pool.ref[p] >= 1, f"tree page {p} freed"
            for key, ch in node.children.items():
                assert ch.parent is node
                assert key == ch.key[:self._ps].tobytes()
                rec(ch)
            for pn in node.partials:
                assert pn.parent is node and pn.partial
                rec(pn)

        rec(self._root)
        assert count == self._nodes, (count, self._nodes)
        assert len(seen) == len(set(seen)), "page owned by two tree nodes"


class ChainPrefixCache:
    """The previous whole-chain rolling-crc32 prefix cache, kept as the
    radix tree's comparison baseline (`prefix_mode="chain"`). Same unified
    interface, but: per-page entries keyed by ``h_i = crc32(page_i tokens,
    h_{i-1})`` (commits to content AND absolute position), ONE partial
    continuation per chain, no recurrent-state snapshots (fully-paged archs
    only), no spill tier — and O(n)-scan eviction."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.has_pages = True
        self._full: OrderedDict[int, int] = OrderedDict()       # chain -> pid
        # chain -> (pid, fill, token bytes): one partial continuation per chain
        self._partial: OrderedDict[int, tuple[int, int, bytes]] = OrderedDict()
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.lookups = 0
        self.state_lookups = 0      # always 0: no snapshots in the baseline
        self.snapshot_hits = 0
        self.snapshots_stored = 0
        self.snapshot_bytes = 0
        self.spills = 0
        self.rehydrates = 0

    def __len__(self) -> int:
        return len(self._full) + len(self._partial)

    @property
    def node_count(self) -> int:
        return len(self)

    @staticmethod
    def _page_hash(tokens: np.ndarray, prev: int) -> int:
        return zlib.crc32(_as_tokens(tokens).tobytes(), prev)

    def evictable(self) -> int:
        return sum(1 for pid in self._full.values()
                   if self.pool.ref[pid] == 1) \
            + sum(1 for pid, _, _ in self._partial.values()
                  if self.pool.ref[pid] == 1)

    def evict_one(self) -> bool:
        """Drop one unpinned entry (oldest first); True if a page was freed."""
        for table in (self._full, self._partial):
            for key, entry in table.items():
                pid = entry if isinstance(entry, int) else entry[0]
                if self.pool.ref[pid] == 1:
                    del table[key]
                    self.pool.decref(pid)
                    return True
        return False

    def match(self, tokens, max_tokens: int, *,
              need_state: bool = False) -> MatchResult:
        assert not need_state, "chain baseline has no state snapshots"
        ps = self.pool.page_size
        tokens = _as_tokens(tokens)
        self.lookup_tokens += len(tokens)
        self.lookups += 1
        pages: list[tuple[int, int]] = []
        covered, chain = 0, 0
        while covered + ps <= max_tokens:
            nxt = self._page_hash(tokens[covered:covered + ps], chain)
            pid = self._full.get(nxt)
            if pid is None:
                break
            chain = nxt
            self._full.move_to_end(chain)
            self.pool.incref(pid)
            pages.append((pid, ps))
            covered += ps
        part = self._partial.get(chain)
        matched_partial = False
        if part is not None:
            pid, fill, blob = part
            if 0 < fill <= max_tokens - covered and \
                    tokens[covered:covered + fill].tobytes() == blob:
                self._partial.move_to_end(chain)
                self.pool.incref(pid)
                pages.append((pid, fill))
                covered += fill
                matched_partial = True
        if not matched_partial and covered + ps == len(tokens) \
                and covered < max_tokens:
            # exact-page-multiple edge (see RadixPrefixCache.match)
            nxt = self._page_hash(tokens[covered:covered + ps], chain)
            pid = self._full.get(nxt)
            if pid is not None:
                self._full.move_to_end(nxt)
                self.pool.incref(pid)
                pages.append((pid, max_tokens - covered))
                covered = max_tokens
        self.hit_tokens += covered
        return MatchResult(pages=pages, covered=covered)

    def abandon(self, mr: MatchResult, lookup_tokens: int) -> None:
        for pid, _ in mr.pages:
            self.pool.decref(pid)
        self.hit_tokens -= mr.covered
        self.lookup_tokens -= lookup_tokens
        self.lookups -= 1

    def release(self, mr: MatchResult) -> None:
        pass                    # chain entries are never pinned by matches

    def match_page(self, tokens, covered: int) -> Optional[int]:
        ps = self.pool.page_size
        assert covered % ps == 0
        tokens = _as_tokens(tokens)
        chain = 0
        for i in range((covered // ps) + 1):
            chain = self._page_hash(tokens[i * ps:(i + 1) * ps], chain)
        pid = self._full.get(chain)
        if pid is None:
            return None
        self._full.move_to_end(chain)
        self.pool.incref(pid)
        self.hit_tokens += ps
        return pid

    def insert_pages(self, tokens, upto_page: int, page_ids: list,
                     registered: int) -> int:
        ps = self.pool.page_size
        tokens = _as_tokens(tokens)
        chain = 0
        for i in range(upto_page):
            chain = self._page_hash(tokens[i * ps:(i + 1) * ps], chain)
            if i < registered:
                continue
            if chain not in self._full:
                self._full[chain] = page_ids[i]
                self.pool.incref(page_ids[i])
        return max(registered, upto_page)

    def insert_partial(self, tokens, pid: int) -> bool:
        ps = self.pool.page_size
        tokens = _as_tokens(tokens)
        fill = len(tokens) % ps
        if fill == 0:
            return False
        chain = 0
        for i in range(len(tokens) // ps):
            chain = self._page_hash(tokens[i * ps:(i + 1) * ps], chain)
        if chain in self._partial:
            return False
        self._partial[chain] = (pid, fill, tokens[-fill:].tobytes())
        self.pool.incref(pid)
        return True

    def wants_snapshot(self, tokens, boundary: int) -> bool:
        return False

    def insert_snapshot(self, tokens, boundary: int, blob) -> bool:
        return False

    def spill_all(self) -> None:
        pass
