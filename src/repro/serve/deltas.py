"""Per-user compact-delta store for the serve engine.

Delta lifecycle (mirrors the refcount/LRU discipline of
`repro.serve.paging.PagePool`, but at user granularity):

1. **admit** — a request arrives carrying a user id. The store looks the
   user's `DeltaState` up (hit) or creates a fresh zero delta with that
   user's fixed channel selection (miss), pins it (refcount +1, one pin per
   in-flight request of that user), and the engine *materializes* it into
   the device-resident per-slot delta batch rows (zero-padded over the
   frozen layer prefix).
2. **decode gather-add** — every decode/prefill step applies the row's
   delta inside the covered matmuls (`repro.models.common.delta_matmul_add`)
   under the one jitted `paged_step`; the user's personalized weights never
   exist densely.
3. **online train** — when the user's request completes, the engine runs a
   compact train wave (`repro.train.steps.make_online_wave`) over the
   request's token stream and writes the advanced delta back via `put`;
   live slots of the same user are re-materialized (a mid-stream delta
   update for their in-flight requests).
4. **evict/spill** — `release` drops the request's pin; unpinned deltas
   stay resident (host numpy — *demoted* from the device rows, which are
   recycled) until capacity forces LRU eviction of the least-recently-used
   unpinned entry. Capacity is a hard bound: admitting a new user when
   every resident delta is pinned raises (like PagePool exhaustion) rather
   than silently growing. `checkpoint.manager.save_delta_store` serializes
   the resident entries so per-user deltas survive restarts.

The store is jax-free: entries are opaque values produced by a
`make_entry(user)` factory, so the invariants are property-testable with
plain dicts/numpy (tests/test_deltas.py).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Optional

__all__ = ["DeltaStore", "PersonalizationConfig"]


class PersonalizationConfig:
    """Knobs for per-user online personalization in the serve engine.

    sparse/optimizer default to a smoke-scale compact-update recipe; the
    optimizer must stay sgd momentum-0 (per-user state = delta only).
    """

    def __init__(self, sparse=None, optimizer=None, *, store_capacity=32,
                 train_tokens: int = 16, use_kernels: bool = False,
                 seed: int = 0):
        from repro.configs.base import OptimizerConfig, SparseUpdateConfig
        self.sparse = sparse or SparseUpdateConfig(
            update_ratio=0.25, num_update_layers=2, channel_block=8)
        self.optimizer = optimizer or OptimizerConfig(
            kind="sgd", learning_rate=0.05)
        self.store_capacity = int(store_capacity)
        self.train_tokens = int(train_tokens)
        self.use_kernels = bool(use_kernels)
        self.seed = int(seed)


class DeltaStore:
    """Refcounted, LRU-evicted, capacity-bounded map user -> delta entry.

    An entry is pinned while any in-flight request of that user holds it
    (one `admit` pin per request, dropped by `release`); only unpinned
    entries are evictable, strictly in least-recently-used order. The entry
    value itself is opaque (`make_entry` factory): the engine stores
    host-resident `DeltaState`s, the property tests store plain dicts.
    """

    def __init__(self, capacity: int, make_entry: Callable[[Any], Any],
                 nbytes: Optional[Callable[[Any], int]] = None):
        assert capacity >= 1
        self.capacity = capacity
        self._make = make_entry
        self._nbytes = nbytes or _default_nbytes
        self._entries: OrderedDict[Any, Any] = OrderedDict()
        self._ref: dict[Any, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- lifecycle ----------------------------------------------------------

    def admit(self, user):
        """Look up (or create) the user's delta and pin it. Raises when the
        store is full of pinned entries (hard capacity bound)."""
        if user in self._entries:
            self.hits += 1
            self._entries.move_to_end(user)
            self._ref[user] += 1
            return self._entries[user]
        self.misses += 1
        if len(self._entries) >= self.capacity and self.evict_lru() is None:
            raise RuntimeError(
                f"delta store exhausted: {self.capacity} entries, all pinned")
        entry = self._make(user)
        self._entries[user] = entry
        self._ref[user] = 1
        return entry

    def release(self, user):
        """Drop one pin. The entry stays resident (LRU-evictable at ref 0);
        releasing below zero is a refcounting bug and raises."""
        if self._ref.get(user, 0) <= 0:
            raise RuntimeError(f"double-free of delta for user {user!r}")
        self._ref[user] -= 1

    def evict_lru(self):
        """Evict the least-recently-used UNPINNED entry; returns the evicted
        user id, or None when every resident entry is pinned."""
        for user in self._entries:
            if self._ref[user] == 0:
                del self._entries[user]
                del self._ref[user]
                self.evictions += 1
                return user
        return None

    # -- access -------------------------------------------------------------

    def get(self, user):
        """Read the user's entry (LRU-touch, no pin)."""
        self._entries.move_to_end(user)
        return self._entries[user]

    def peek(self, user):
        """Read without touching LRU order (checkpointing, tests)."""
        return self._entries[user]

    def put(self, user, entry):
        """Replace a resident user's entry (post-train-wave writeback)."""
        if user not in self._entries:
            raise KeyError(user)
        self._entries[user] = entry
        self._entries.move_to_end(user)

    def load(self, user, entry):
        """Insert a restored entry unpinned (checkpoint restore path);
        honors the capacity bound."""
        if user not in self._entries and len(self._entries) >= self.capacity \
                and self.evict_lru() is None:
            raise RuntimeError(
                f"delta store exhausted: {self.capacity} entries, all pinned")
        self._entries[user] = entry
        self._ref.setdefault(user, 0)
        self._entries.move_to_end(user)

    def users(self):
        """Resident user ids in LRU order (least recent first)."""
        return list(self._entries)

    def ref(self, user) -> int:
        return self._ref.get(user, 0)

    @property
    def resident_bytes(self) -> int:
        return sum(self._nbytes(e) for e in self._entries.values())

    def __len__(self):
        return len(self._entries)

    def __contains__(self, user):
        return user in self._entries

    # -- invariants ---------------------------------------------------------

    def check(self):
        assert len(self._entries) <= self.capacity, \
            f"capacity exceeded: {len(self._entries)} > {self.capacity}"
        assert set(self._entries) == set(self._ref)
        assert all(r >= 0 for r in self._ref.values())


def _default_nbytes(entry) -> int:
    nb = getattr(entry, "nbytes", None)
    if nb is not None:
        return int(nb)
    import jax
    return sum(int(a.nbytes) for a in jax.tree.leaves(entry))
