"""Token sampling for the serving engine: greedy + temperature.

`temperature` is static (baked into the jitted step): <= 0 means greedy
argmax; > 0 scales the logits and draws from the categorical. Per-step keys
are split by the engine so consecutive steps never reuse randomness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits, key, temperature: float = 0.0):
    """logits [B, V] -> token ids [B] (int32)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)
