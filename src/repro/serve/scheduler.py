"""Slot scheduler for continuous batching over paged caches.

Pure-python state machine, no jax: the engine asks it which slots to admit
or chunk-prefill and reports sampled tokens back; the scheduler decides
admission, completion, and cancellation. Slot indices are batch rows of the
engine's per-slot state cache (and rows of its page-table array).

Slot life cycle::

    FREE --admit--> PREFILL --last chunk--> ACTIVE --finish/cancel--> FREE

Admission no longer runs a monolithic prefill: a PREFILL slot consumes its
prompt in page-sized chunks, one chunk per engine iteration, while ACTIVE
slots keep decoding — a long prompt never stalls in-flight requests.

Accounting: `tokens_out` / `requests_completed` are credited at FINISH
time only. A cancelled request (streaming callback returned False, or its
deadline passed) moves its tokens to `tokens_cancelled` instead — cancelled
work never inflates throughput numbers (the PR-2 pad-slot bug class, now
for cancellations).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Callable, Optional

import numpy as np


class SlotState(enum.Enum):
    FREE = "free"
    PREFILL = "prefill"
    ACTIVE = "active"


@dataclasses.dataclass
class Request:
    """One generation request. `tokens` for token-input models, `embeds`
    ([prompt_len, d_model]) for embed-input frontends (musicgen-style).

    `stream` is the per-token callback ``fn(rid, token) -> bool | None``:
    called for every sampled token in order; returning False cancels the
    request mid-stream. `timeout_s` is a wall-clock budget from submission
    — a request past its deadline is cancelled (or dropped from the queue
    without ever being admitted).

    `user` routes the request to a per-user compact delta when the engine
    is built with a `PersonalizationConfig`: decode applies that user's
    delta (gather-add), and completion feeds an online train wave that
    advances it. None = plain base-model serving for this request.
    """
    rid: int
    max_new_tokens: int
    tokens: Optional[np.ndarray] = None
    embeds: Optional[np.ndarray] = None
    stream: Optional[Callable[[int, int], Optional[bool]]] = None
    timeout_s: Optional[float] = None
    user: Optional[object] = None

    @property
    def prompt_len(self) -> int:
        src = self.tokens if self.tokens is not None else self.embeds
        return int(src.shape[0])


@dataclasses.dataclass
class Slot:
    index: int
    state: SlotState = SlotState.FREE
    request: Optional[Request] = None
    # position of the next token to *consume* == tokens cached so far. A
    # freshly sampled token has NOT been cached yet: the engine advances
    # pos only after the step that consumes it (feeding the sampled token
    # at RoPE position `pos`), never at sampling time.
    pos: int = 0
    prefilled: int = 0        # prompt tokens already cached (chunked prefill)
    generated: int = 0        # tokens sampled for the current request
    last_token: int = 0       # fed to the next decode step
    out_tokens: list = dataclasses.field(default_factory=list)
    deadline: Optional[float] = None
    # engine-owned paging state for the current request
    page_ids: list = dataclasses.field(default_factory=list)
    registered_pages: int = 0  # prefix-cache registration watermark
    match: Optional[object] = None  # pinned prefix-cache MatchResult
    # engine-owned robustness state for the current request
    retries: int = 0           # transient faults absorbed so far
    retry_at: float = 0.0      # wall clock before which the slot backs off
    last_progress: float = 0.0  # watchdog: last time pos advanced


class Scheduler:
    """FIFO admission over a fixed slot set.

    The engine drives it with: `peek_admission()` / `commit_admission()`
    (two-phase, so the engine can veto on page-pool pressure),
    `prefill_slots()` for chunking, `active_slots()` for the decode mask,
    `record_token(slot, tok)` after sampling (True when the request
    completed), and `cancel(slot)` / `drop_queued(req)` for cancellation.
    """

    def __init__(self, num_slots: int, eos_id: Optional[int] = None):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.queue: collections.deque[Request] = collections.deque()
        self.eos_id = eos_id
        self.requests_completed = 0
        self.requests_cancelled = 0
        self.requests_quarantined = 0
        self.tokens_out = 0
        self.tokens_cancelled = 0
        self.tokens_quarantined = 0
        self.refills = 0          # admissions into a previously-used slot

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def peek_admission(self):
        """Next (slot, request) that COULD be admitted, or None. Does not
        change any state — the engine may decline (no pages) and retry on a
        later iteration without disturbing FIFO order."""
        if not self.queue:
            return None
        for slot in self.slots:
            if slot.state is SlotState.FREE:
                return slot, self.queue[0]
        return None

    def commit_admission(self, slot: Slot, prefilled: int = 0) -> Request:
        """Bind the queue head to `slot` and start chunked prefill.
        `prefilled` > 0 when a prompt-prefix cache hit pre-populated the
        first pages (the engine set the page table accordingly)."""
        req = self.queue.popleft()
        if slot.request is not None:
            self.refills += 1
        slot.state = SlotState.PREFILL
        slot.request = req
        slot.pos = prefilled
        slot.prefilled = prefilled
        slot.generated = 0
        slot.out_tokens = []
        slot.retries = 0
        slot.retry_at = 0.0
        return req

    def prefill_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state is SlotState.PREFILL]

    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state is SlotState.ACTIVE]

    def live_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state is not SlotState.FREE]

    def finish_prefill(self, slot: Slot) -> None:
        assert slot.state is SlotState.PREFILL
        assert slot.pos == slot.request.prompt_len
        slot.state = SlotState.ACTIVE

    def record_token(self, slot: Slot, token: int):
        """Account one sampled token for an ACTIVE slot; finish the request
        on max_new_tokens or EOS, cancel it if its streaming callback says
        stop. Returns "done", "cancelled", or None (still generating).
        Tokens are credited to the global counters only at completion."""
        assert slot.state is SlotState.ACTIVE
        slot.out_tokens.append(token)
        slot.last_token = token
        slot.generated += 1
        req = slot.request
        if req.stream is not None and req.stream(req.rid, token) is False:
            self.cancel(slot)
            return "cancelled"
        done = slot.generated >= req.max_new_tokens
        if self.eos_id is not None and token == self.eos_id:
            done = True
        if done:
            slot.state = SlotState.FREE
            self.requests_completed += 1
            self.tokens_out += slot.generated
            return "done"
        return None

    def cancel(self, slot: Slot) -> None:
        """Cancel a PREFILL/ACTIVE request: its tokens never count toward
        completed-request or throughput accounting."""
        assert slot.state is not SlotState.FREE
        self.requests_cancelled += 1
        self.tokens_cancelled += slot.generated
        slot.state = SlotState.FREE

    def quarantine(self, slot: Slot) -> None:
        """Close a poison request (exhausted its retry budget, or tripped
        the hung-request watchdog): the slot is freed for the next
        admission, and the request's tokens land in dedicated quarantine
        counters — never in throughput, never silently dropped."""
        assert slot.state is not SlotState.FREE
        self.requests_quarantined += 1
        self.tokens_quarantined += slot.generated
        slot.state = SlotState.FREE

    def drop_queued(self, request: Request) -> None:
        """Cancel a request still in the queue (deadline passed unadmitted)."""
        self.queue.remove(request)
        self.requests_cancelled += 1

    @property
    def done(self) -> bool:
        return not self.queue and all(
            s.state is SlotState.FREE for s in self.slots)
