"""Slot scheduler for continuous batching.

Pure-python state machine, no jax: the engine asks it which slots to refill
and reports sampled tokens back; the scheduler decides admission and
completion. Slot indices are batch rows of the engine's cache.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Optional

import numpy as np


class SlotState(enum.Enum):
    FREE = "free"
    ACTIVE = "active"


@dataclasses.dataclass
class Request:
    """One generation request. `tokens` for token-input models, `embeds`
    ([prompt_len, d_model]) for embed-input frontends (musicgen-style)."""
    rid: int
    max_new_tokens: int
    tokens: Optional[np.ndarray] = None
    embeds: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        src = self.tokens if self.tokens is not None else self.embeds
        return int(src.shape[0])


@dataclasses.dataclass
class Slot:
    index: int
    state: SlotState = SlotState.FREE
    request: Optional[Request] = None
    # position of the next token to *consume* == tokens cached so far. A
    # freshly sampled token has NOT been cached yet: the engine advances
    # pos only after the decode step that consumes it (feeding the sampled
    # token at RoPE position `pos`), never at sampling time.
    pos: int = 0
    generated: int = 0        # tokens sampled for the current request
    last_token: int = 0       # fed to the next decode step
    out_tokens: list = dataclasses.field(default_factory=list)


class Scheduler:
    """FIFO admission over a fixed slot set.

    The engine drives it with three calls per iteration:
    `next_admission()` until None (slot, request pairs to prefill),
    `active_slots()` for the decode mask, and `record_token(slot, tok)`
    after sampling — which returns True when the request completed.
    """

    def __init__(self, num_slots: int, eos_id: Optional[int] = None):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.queue: collections.deque[Request] = collections.deque()
        self.eos_id = eos_id
        self.requests_completed = 0
        self.tokens_out = 0
        self.refills = 0          # admissions into a previously-used slot

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def next_admission(self):
        """Pop (slot, request) to admit, or None if no free slot or empty
        queue. A slot finished on a previous iteration is handed out here
        immediately — the batch is never drained."""
        if not self.queue:
            return None
        for slot in self.slots:
            if slot.state is SlotState.FREE:
                req = self.queue.popleft()
                if slot.request is not None:
                    self.refills += 1
                slot.state = SlotState.ACTIVE
                slot.request = req
                slot.pos = req.prompt_len
                slot.generated = 0
                slot.out_tokens = []
                return slot, req
        return None

    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state is SlotState.ACTIVE]

    def record_token(self, slot: Slot, token: int) -> bool:
        """Account one sampled token for an ACTIVE slot; finish the request
        on max_new_tokens or EOS. Returns True iff the request completed."""
        assert slot.state is SlotState.ACTIVE
        slot.out_tokens.append(token)
        slot.last_token = token
        slot.generated += 1
        self.tokens_out += 1
        done = slot.generated >= slot.request.max_new_tokens
        if self.eos_id is not None and token == self.eos_id:
            done = True
        if done:
            slot.state = SlotState.FREE
            self.requests_completed += 1
        return done

    @property
    def done(self) -> bool:
        return not self.queue and not self.active_slots()
