"""Paged continuous-batching serving subsystem: paging + scheduler + engine.

Cache layout (the paper's fixed-block memory discipline, applied to decode)
---------------------------------------------------------------------------
The engine owns a fixed number of decode *slots* and a fixed page pool.
Per-layer caches split into two trees (``models/decoding.py``):

- **state** — per-slot leaves ``[scan_steps, num_slots, ...]``: ring-buffer
  k/v for sliding-window layers, recurrent state for mamba/rwkv layers. A
  state family is O(1) per slot — effectively a single resident "page" —
  so it keeps its contiguous layout behind the same admission path.
- **pools** — for every window-free attention layer, a physical token-row
  pool ``[scan_steps, num_pages * page_size, Hkv, D]`` shared by ALL slots.
  A per-slot page table (``[num_slots, ceil(max_len/page_size)]`` int32,
  -1 = unallocated) maps logical page i -> physical page, and ONE page id
  indexes every layer's pool simultaneously (vLLM-style). Attention reads
  gather rows through the page table; writes scatter through it, so a slot
  reserves pages as it grows instead of ``max_len`` contiguous rows.

Pages are refcounted (``paging.PagePool``): a live request holds one
reference per table entry, the prefix cache one per node-owned page, and
any write into a page with refcount > 1 first COW-splits it. Prompt-prefix
sharing (``paging.RadixPrefixCache``) keys reuse by token content in a
radix tree over pages, so requests share arbitrary page-aligned prefixes
up to their divergence point — and EVERY cache family participates: paged
layers share the pages themselves, while ring/recurrent state is captured
as host snapshots at page boundaries during prefill and restored at
admission (``models/decoding.py`` CacheFamily). Evicted tree nodes spill
to a host LRU tier (``paging.SpillTier``) that outlives ``run()`` and,
via ``checkpoint/manager.py`` + ``--prefix-persist``, engine restarts.
The legacy whole-chain hash design survives as ``ChainPrefixCache``
(``prefix_mode="chain"``), the radix tree's comparison baseline.

Slot life cycle::

    FREE --admit--> PREFILL --last chunk--> ACTIVE --finish/cancel--> FREE
          (attach shared prefix   (first token     (completed: tokens are
           pages, then chunked     sampled from     credited; cancelled:
           prefill, one page-      the final        they are not; pages
           sized chunk per         chunk's logits)  decref'd either way)
           engine iteration)

Admission is per-slot and page-gated: a finished slot is re-admitted from
the queue on the very next iteration while other slots keep decoding, and
a request is only admitted when the pool can cover its worst-case page
need (so mid-flight allocation never deadlocks). Chunked prefill and
batched decode are the SAME jitted ``paged_step``; inactive batch rows
keep their state bit-for-bit and their page writes are dropped, so padded
slots never corrupt caches — and never count as requests or tokens.

Accounting: ``requests_completed``/``tokens_out`` count FINISH transitions
only. Streaming callbacks (``Request.stream``) see every token in order
and may cancel mid-stream; cancelled and timed-out requests land in
``requests_cancelled``/``tokens_cancelled`` and never inflate throughput.

Per-user personalization (``deltas.DeltaStore`` + ``core/delta.py``): an
engine built with a ``PersonalizationConfig`` routes ``Request.user`` to a
compact per-user parameter delta — applied at decode as a gather-add inside
the jitted step, advanced by an online compact train wave when that user's
requests complete, and LRU-evicted under a hard capacity bound. The shared
base model is never written.
"""
from repro.serve.deltas import DeltaStore, PersonalizationConfig
from repro.serve.engine import (RequestResult, ServeEngine, ServeStats,
                                make_branching_prefix_requests,
                                make_random_requests,
                                make_shared_prefix_requests)
from repro.serve.journal import RequestJournal
from repro.serve.paging import (ChainPrefixCache, MatchResult, PagePool,
                                RadixPrefixCache, SpillTier)
from repro.serve.sampling import sample_token
from repro.serve.scheduler import Request, Scheduler, Slot, SlotState

__all__ = [
    "ChainPrefixCache", "DeltaStore", "MatchResult", "PagePool",
    "PersonalizationConfig", "RadixPrefixCache", "Request", "RequestJournal",
    "RequestResult",
    "Scheduler", "ServeEngine", "ServeStats", "Slot", "SlotState",
    "SpillTier", "sample_token", "make_branching_prefix_requests",
    "make_random_requests", "make_shared_prefix_requests",
]
