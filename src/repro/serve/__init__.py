"""Continuous-batching serving subsystem: scheduler + engine + sampling.

The engine owns a fixed number of decode *slots* (batch rows of the stacked
per-layer caches from ``models/decoding.py``). Each slot runs the state
machine::

    FREE --admit--> ACTIVE --finish--> FREE
          (batch=1 prefill of the next   (max_new_tokens reached, or the
           queued request, spliced into   sampled token == eos_id; the row
           the batch cache row via        is left dirty and fully
           cache_insert_row)              overwritten on the next admit)

Admission is per-slot: a finished slot is re-prefilled from the queue on the
very next engine iteration while the other slots keep decoding — the batch is
never drained. Each engine iteration is (1) refill every FREE slot while the
queue is non-empty, then (2) one jitted fixed-shape ``decode_step`` over all
slots with per-slot positions. FREE slots still flow through the batched
decode (fixed shapes), but an active-slot mask keeps their tokens out of
sampling results and out of every throughput/latency counter — padded slots
are never counted as requests or tokens.

Request/token accounting is therefore correct by construction:
``requests_completed`` counts FINISH transitions and ``tokens_out`` counts
sampled tokens on ACTIVE slots only.
"""
from repro.serve.engine import RequestResult, ServeEngine, ServeStats
from repro.serve.sampling import sample_token
from repro.serve.scheduler import Request, Scheduler, Slot, SlotState

__all__ = [
    "Request", "RequestResult", "Scheduler", "ServeEngine", "ServeStats",
    "Slot", "SlotState", "sample_token",
]
