"""Continuous-batching engine over a paged KV cache.

One engine iteration:

1. *Timeouts*: requests past their deadline are cancelled (queued ones are
   dropped without admission); their tokens never reach the throughput
   counters.
2. *Admission*: while a FREE slot and a queued request exist AND the page
   pool can cover the request's worst-case page need, bind the request to
   the slot. Prompt-prefix sharing (every cache family) attaches cached
   pages from the radix tree — and, for archs with ring/recurrent state,
   restores the recurrent-state snapshot at the deepest matched page
   boundary — so the matched prefix tokens are never recomputed. The
   match stays pinned in the tree until the slot closes.
3. *Chunked prefill*: every PREFILL slot advances by ONE page-sized chunk
   through the same ``paged_step`` the decode uses (B=1), so a long prompt
   admission never stalls in-flight decodes. The final chunk's logits yield
   the request's first sampled token.
4. *Decode*: one jitted fixed-shape ``paged_step`` over all slots (S=1)
   with per-slot start positions and an active mask; inactive rows keep
   their state bit-for-bit and their page writes are dropped.

Copy-on-write: any write into a page shared with the prefix cache or
another request first COW-splits it (exclusive copy of the device rows in
every layer's pool). The canonical trigger: a request registers its
partially-filled last prompt page, then COWs it on its first decode write,
leaving the cached page frozen at prompt-only content.

Prefix reuse modes (`prefix_mode`): "radix" (default) is the radix tree
over token pages with recurrent-state snapshots and the host spill tier —
evicted/ended trees survive across `run()` calls and, with
`prefix_persist`, across engine restarts. "chain" is the legacy flat
chain-hash baseline (fully-paged archs only, dies with `run()`), kept for
comparison. "off" disables sharing entirely.

PRNG: the engine key is split every step, so temperature sampling and the
placeholder-embeds input path (``cfg.embed_inputs`` frontends) never reuse
a key across steps.

Robustness (``runtime/chaos.py`` is the serve-side fault story):

- *Deterministic fault injection*: a seeded ``FaultSchedule`` makes page
  allocations, prefill/decode steps, and stream callbacks fail (or run
  slow) on a replayable schedule. Step faults fire BEFORE the jitted call
  and alloc faults before any pool mutation, so every injected failure is
  retryable without state repair — under greedy decoding, faults change
  latency and counters, never served tokens (pinned by parity tests).
- *Graceful degradation*: a faulted slot retries with capped exponential
  backoff (its batch row is masked out, state frozen bit-for-bit); a
  request that exhausts ``max_retries`` — or trips the hung-request
  watchdog — is closed as "quarantined" so one poison request can never
  wedge a slot; admission sheds (defers) load when free pages would drop
  below ``shed_watermark`` (shed requests keep their `timeout_s`
  accounting); stream-callback exceptions are absorbed, not fatal; every
  engine iteration feeds a ``StragglerMonitor``.
- *Crash safety*: with ``journal=...`` every admission/completion is
  fsynced to an append-only request journal; ``recover_requests()`` on a
  restarted engine replays in-flight requests, and the prefix spill tier
  (flushed on BOTH clean exit and crash unwind) turns their re-prefill
  into prefix/snapshot hits. ``InjectedCrash`` (``kill_after``) simulates
  the hard kill end to end.
"""
from __future__ import annotations

import dataclasses
import os
import time
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (CheckpointCorruptError,
                                      restore_spill_tier, save_spill_tier)
from repro.models import decoding as D
from repro.runtime.chaos import FaultKind, InjectedCrash, InjectedFault
from repro.runtime.fault import StragglerMonitor
from repro.serve.deltas import DeltaStore, PersonalizationConfig
from repro.serve.journal import RequestJournal
from repro.serve.paging import (ChainPrefixCache, PagePool, RadixPrefixCache,
                                SpillTier)
from repro.serve.sampling import sample_token
from repro.serve.scheduler import Request, Scheduler, Slot, SlotState

__all__ = ["RequestResult", "ServeEngine", "ServeStats",
           "make_random_requests", "make_shared_prefix_requests",
           "make_branching_prefix_requests"]


def _graft_like(tpl, blob):
    """Re-attach empty subtrees that checkpoint serialization drops: walk
    the template's dict skeleton and take `blob`'s value wherever the
    template has leaves below. Host trees only (structure work, no data)."""
    if isinstance(tpl, dict):
        return {k: _graft_like(v, blob.get(k, {}) if isinstance(blob, dict)
                               else {}) for k, v in tpl.items()}
    return blob


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: list            # sampled token ids, in order
    latency_s: float        # submit -> completion (includes queueing)
    status: str = "completed"   # completed | cancelled


@dataclasses.dataclass
class ServeStats:
    requests_completed: int
    requests_cancelled: int
    tokens_out: int         # tokens of COMPLETED requests only
    tokens_cancelled: int
    wall_s: float
    tok_per_s: float
    latency_p50_s: float
    latency_p95_s: float
    refills: int            # admissions that recycled a dirty slot
    prefill_chunks: int     # chunked-prefill steps run
    prefix_hit_tokens: int  # prompt tokens served from shared pages
    prefix_lookup_tokens: int
    pages_total: int        # page-pool capacity
    pages_peak: int         # peak pages in use (sharing lowers this)
    cow_splits: int
    results: dict           # rid -> RequestResult
    # prefix-reuse internals (zero when prefix_mode != "radix")
    prefix_mode: str = "off"
    prefix_lookups: int = 0         # admission-time cache lookups
    state_lookups: int = 0          # lookups that asked for a state snapshot
    radix_nodes: int = 0            # tree nodes at end of run
    snapshot_hits: int = 0          # matches that restored recurrent state
    snapshots_stored: int = 0
    spills: int = 0                 # entries written to the host spill tier
    rehydrates: int = 0             # spilled entries re-attached on match
    spill_entries: int = 0          # tier size at end of run
    # per-user personalization (all zero when the engine has none)
    delta_hits: int = 0             # delta-store admissions that hit
    delta_lookups: int = 0          # delta-store admissions total
    delta_evictions: int = 0
    delta_resident_bytes: int = 0   # host bytes of resident deltas at end
    train_waves: int = 0            # online train waves run
    train_wave_s: float = 0.0       # wall time spent in train waves
    wave_losses: list = dataclasses.field(default_factory=list)
    # (user, pre-update loss) per wave, in wave order
    # robustness / chaos (all zero without a FaultSchedule / journal)
    faults_injected: int = 0        # chaos draws that fired during this run
    faults_by_kind: dict = dataclasses.field(default_factory=dict)
    retries: int = 0                # transient faults absorbed by backoff
    sheds: int = 0                  # requests deferred by the load-shed watermark
    quarantined: int = 0            # requests closed as poison
    tokens_quarantined: int = 0
    watchdog_kills: int = 0         # quarantines from the hung-request watchdog
    stream_errors: int = 0          # stream-callback exceptions absorbed
    journal_replays: int = 0        # re-admissions recovered from the journal
    stragglers: int = 0             # engine iterations flagged as stragglers
    # sharded serving (defaults = single-device engine)
    mesh_shards: int = 1            # model-axis shards the pools split into
    pool_shard_bytes: int = 0       # page-pool bytes resident per shard
    # phase-split throughput: wall time spent inside the jitted step (host
    # sync included) and tokens processed, split prefill vs decode — the
    # mesh sweep reports these per mesh row since the two phases scale
    # differently with tensor parallelism
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0         # VALID prompt tokens prefilled (pad excl.)
    decode_tokens: int = 0          # tokens sampled for runnable slots

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(1, self.prefix_lookup_tokens)

    @property
    def page_util(self) -> float:
        return self.pages_peak / max(1, self.pages_total)

    @property
    def snapshot_hit_rate(self) -> float:
        """Snapshot hits over STATE-FAMILY lookups only. Attention-family
        lookups never ask for a snapshot, so denominating by ALL prefix
        lookups (the old behaviour) diluted the rate toward zero on mixed
        llama3+jamba workloads."""
        return self.snapshot_hits / max(1, self.state_lookups)

    @property
    def delta_hit_rate(self) -> float:
        return self.delta_hits / max(1, self.delta_lookups)

    @property
    def prefill_tok_per_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / max(self.decode_s, 1e-9)

    @property
    def train_wave_ms_per_token(self) -> float:
        """Train-wave overhead in MILLISECONDS amortized over every decoded
        token. `train_wave_s` is seconds; the former `wave_s_per_token`
        name left the *1e3 to each call site — one missed conversion
        under-reported wave cost by 1000x, so the property now owns it."""
        return self.train_wave_s * 1e3 / max(1, self.tokens_out)


class ServeEngine:
    """Paged continuous-batching serve loop for one model + parameter set."""

    def __init__(self, cfg, params, *, num_slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0, page_size: int = 16,
                 num_pages: Optional[int] = None, prefix_sharing: bool = True,
                 prefix_mode: str = "radix",
                 prefix_persist: Optional[str] = None,
                 spill_entries: int = 4096, snapshot_budget: int = 256,
                 max_tree_nodes: int = 4096,
                 personalization: Optional[PersonalizationConfig] = None,
                 chaos=None, max_retries: int = 3,
                 retry_backoff_s: float = 0.005,
                 retry_backoff_cap_s: float = 0.1,
                 shed_watermark: float = 0.0,
                 watchdog_s: Optional[float] = None,
                 journal=None, straggler_factor: float = 2.5,
                 rules=None, flash_decode: Optional[bool] = None):
        assert num_slots >= 1 and max_len >= 2 and page_size >= 1
        assert prefix_mode in ("radix", "chain", "off")
        assert max_retries >= 0 and 0.0 <= shed_watermark < 1.0
        # robustness knobs (see the module docstring's Robustness section);
        # `chaos` is a runtime.chaos.FaultSchedule or None — every injection
        # point is gated on it, so a chaos-free engine runs the exact
        # pre-chaos code path
        self.chaos = chaos
        self.max_retries = max_retries
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        self.shed_watermark = float(shed_watermark)
        self.watchdog_s = watchdog_s
        self._straggler_factor = straggler_factor
        self._journal = RequestJournal(journal) if isinstance(journal, str) \
            else journal
        self._stream_errors = 0
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        self.has_pages = D.has_paged_layers(cfg)
        self._need_state = D.has_state_layers(cfg)
        # default pool = contiguous capacity (num_slots full-length tables);
        # prefix sharing makes the PEAK usage come in under it. State-only
        # archs (rwkv) have no paged layers and no pool at all.
        if not self.has_pages:
            self.num_pages = 0
        else:
            self.num_pages = num_pages if num_pages is not None else \
                num_slots * self.max_pages
        # Placeholder-embeds frontends have no token identity to key reuse
        # on; the chain baseline additionally needs every layer paged (it
        # has no snapshots to cover ring/recurrent state).
        if not prefix_sharing or cfg.embed_inputs:
            prefix_mode = "off"
        elif prefix_mode == "chain" and (self._need_state
                                         or not self.has_pages):
            prefix_mode = "off"
        self.prefix_mode = prefix_mode
        self.prefix_sharing = prefix_mode != "off"
        self.snapshot_budget = snapshot_budget
        self.max_tree_nodes = max_tree_nodes
        # ONE spill tier per engine, shared by every run()'s tree: prefix
        # state survives pool teardown (and, with prefix_persist, restarts)
        self._spill = SpillTier(spill_entries) \
            if prefix_mode == "radix" else None
        self._cache = None      # built per run(); None until the first run
        self._persist_path = None
        if prefix_persist is not None and self._spill is not None:
            os.makedirs(prefix_persist, exist_ok=True)
            self._persist_path = os.path.join(prefix_persist,
                                              "prefix_tree.ckpt")
            if os.path.exists(self._persist_path):
                try:
                    meta = restore_spill_tier(self._persist_path, self._spill)
                except CheckpointCorruptError as e:
                    # torn persist file (crash mid-write): cold start beats
                    # crashing the restart
                    import warnings
                    warnings.warn(f"prefix-persist tree is corrupt ({e}); "
                                  "starting cold")
                    self._spill.clear()
                    meta = {"page_size": page_size, "max_len": max_len,
                            "model": cfg.name}
                if (meta.get("page_size") != page_size
                        or meta.get("max_len") != max_len
                        or meta.get("model") != cfg.name):
                    self._spill.clear()     # incompatible tree: start cold
        self.temperature = float(temperature)
        self.eos_id = eos_id
        self._key = jax.random.PRNGKey(seed)
        self._zero_key = jax.random.PRNGKey(0)
        self._decode_length = jnp.ones((num_slots,), jnp.int32)

        # sharded serving: with AxisRules carrying a mesh + model axis, the
        # step runs through shard_map — page pools shard over KV heads, page
        # tables / batch / slot state stay replicated (see models/decoding
        # `make_sharded_paged_step`). flash_decode defaults on when sharded
        # (that is the point of splitting long contexts across cores) and
        # off single-device, keeping that path bit-identical.
        self.rules = rules
        self.mesh_shards = 1
        if rules is not None:
            if rules.mesh is None or rules.model_axis is None:
                raise ValueError(
                    "sharded serving needs AxisRules built from a mesh with "
                    f"a model axis (got mesh={rules.mesh!r}, "
                    f"model_axis={rules.model_axis!r})")
            self.mesh_shards = D.validate_pool_sharding(cfg, rules)
        self.flash_decode = flash_decode if flash_decode is not None \
            else rules is not None

        ps = page_size
        # personalization trains its waves on the ORIGINAL replicated params
        # (on a mesh, self.params becomes a sharded copy below): waves must
        # be bit-identical to a single-device engine's, or the deltas — and
        # therefore the served tokens — would diverge across mesh sizes
        self._host_params = params
        if rules is not None:
            from repro.sharding import spec_tree_to_shardings
            self.params = params = jax.device_put(
                params, spec_tree_to_shardings(
                    rules.mesh, D.paged_param_specs(cfg, params, rules)))
            self._step = D.make_sharded_paged_step(
                cfg, rules, params, page_size=ps,
                flash_decode=self.flash_decode)
        else:
            fd = self.flash_decode
            self._step = jax.jit(
                lambda p, batch, state, pools, pt, deltas: D.paged_step(
                    cfg, p, batch, state, pools, pt, page_size=ps,
                    deltas=deltas, flash_decode=fd))
        # on a mesh, pin every pool/state-producing helper to the canonical
        # layout (pools sharded over KV heads, state replicated): otherwise
        # a COW split or row insert hands the next step a differently-laid-
        # out input, costing a duplicate jit cache entry per batch shape and
        # letting pools silently degrade to a replicated (full-size) layout
        pool_out = state_out = None
        if rules is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            pool_out = NamedSharding(rules.mesh, D.pool_pspec(rules))
            state_out = NamedSharding(rules.mesh, PartitionSpec())
        self._state_shard = state_out
        self._extract = jax.jit(D.cache_extract_row, out_shardings=state_out)
        self._insert = jax.jit(D.cache_insert_row, out_shardings=state_out)
        self._reset = jax.jit(D.cache_reset_row, out_shardings=state_out)
        self._copy = jax.jit(
            lambda pools, src, dst: D.copy_pool_rows(pools, src, dst, ps),
            out_shardings=pool_out)
        self._read_rows = jax.jit(
            lambda pools, src: D.read_pool_rows(pools, src, ps))
        self._write_rows = jax.jit(D.write_pool_rows,
                                   out_shardings=pool_out)
        self._sample = jax.jit(
            lambda logits, key: sample_token(logits, key, self.temperature))

        self._p13n = personalization
        self._dbatch = None
        if personalization is not None:
            self._init_personalization()

    # -- per-user personalization ------------------------------------------

    def _init_personalization(self):
        """Build the delta-aware serving pieces: the selection plan pruned to
        decode-coverable leaves, the frozen/trainable base split, the online
        train wave, and the per-user delta store. Requests with user=None
        keep zero delta rows (an exact no-op) under the SAME jitted step."""
        from repro.core import build_plan, random_selection
        from repro.core.delta import (DeltaState, decode_delta_spec,
                                      zeros_delta_tree)
        from repro.train.steps import make_online_wave, split_params

        p = self._p13n
        assert not self.cfg.embed_inputs, (
            "personalization trains on token streams; embed-input frontends "
            "have none")
        plan = build_plan(self.cfg, p.sparse, 0)
        frozen, trainable = split_params(self._host_params, plan)
        spec = decode_delta_spec(plan, trainable["segments"])
        if not spec:
            raise ValueError(
                "no decode-coverable selectable leaves for this arch "
                "(personalized decode covers attn/mlp projections only)")
        # train exactly what decode can apply: waves update only the covered
        # leaves, so the served model IS the trained one
        self._plan = dataclasses.replace(plan, spec=spec)
        self._frozen, self._trainable = frozen, trainable
        self._seg_steps = {
            seg: int(jax.tree.leaves(self.params["segments"][seg])[0].shape[0])
            for seg in spec}
        self._delta_key = jax.random.PRNGKey(p.seed)
        self._wave = jax.jit(make_online_wave(
            self.cfg, p.sparse, p.optimizer, self._plan,
            wave_tokens=p.train_tokens, kernels=p.use_kernels))
        self._zeros_delta = zeros_delta_tree
        self._deltas = DeltaStore(p.store_capacity, self._make_delta_entry)
        self._DeltaState = DeltaState
        self._random_selection = random_selection

    def _make_delta_entry(self, user):
        """Fresh zero delta with this user's fixed channel selection (the
        user id seeds the selection, so it is stable across evictions)."""
        salt = zlib.crc32(str(user).encode()) & 0x7FFFFFFF
        key = jax.random.fold_in(self._delta_key, salt)
        idx_dev = self._random_selection(self._plan, key)
        idx = {seg: jax.tree.map(np.asarray, idx_dev[seg])
               for seg in self._plan.spec}
        vals = self._zeros_delta(self._trainable["segments"], idx,
                                 self._plan.spec, xp=np)
        return self._DeltaState(idx=idx, vals=vals)

    def _delta_batch_zeros(self):
        """Device-resident per-slot delta rows, all zero: {seg: {"idx",
        "val"}} with leaves [scan_steps, num_slots, ...] so they ride the
        layer scan next to the params (zero rows over the frozen prefix and
        for non-personalized slots)."""
        from repro.core.sparse_update import SelSpec
        b = self.num_slots
        out = {}
        for seg, spec in self._plan.spec.items():
            steps = self._seg_steps[seg]
            is_sp = lambda x: isinstance(x, SelSpec)
            idx = jax.tree.map(
                lambda sp: jnp.zeros((steps, b, sp.n_shards, sp.n_sel),
                                     jnp.int32), spec, is_leaf=is_sp)

            def wv(stack, sp):
                if isinstance(sp, SelSpec):
                    d_in = stack.shape[1]
                    return jnp.zeros(
                        (steps, b, d_in, sp.n_shards, sp.n_sel, sp.block),
                        jnp.float32)
                return {k: wv(stack[k], sp[k]) for k in sp}

            out[seg] = {"idx": idx,
                        "val": wv(self._trainable["segments"][seg], spec)}
        return out

    def _delta_row_tree(self, entry):
        """Lift a host DeltaState into [scan_steps, 1, ...] device rows,
        zero-padded over the frozen layer prefix (trainable = LAST K steps),
        ready for `cache_insert_row` into the slot's delta batch row."""
        out = {}
        for seg in self._plan.spec:
            steps = self._seg_steps[seg]

            def pad(leaf, dt):
                src = np.zeros((steps, 1) + leaf.shape[1:], dt)
                src[steps - leaf.shape[0]:, 0] = leaf
                return jnp.asarray(src)

            out[seg] = {
                "idx": jax.tree.map(lambda a: pad(a, np.int32),
                                    entry.idx[seg]),
                "val": jax.tree.map(lambda a: pad(a, np.float32),
                                    entry.vals[seg]),
            }
        return out

    def _online_wave(self, slot, sched):
        """Run one compact train wave on the completed request's token
        stream, advance the user's delta in the store, and re-materialize
        the delta rows of any live slot of the same user (their in-flight
        decode picks up the update mid-stream)."""
        req = slot.request
        p = self._p13n
        stream = np.concatenate([
            np.asarray(req.tokens, np.int64),
            np.asarray(slot.out_tokens, np.int64)])
        n = p.train_tokens
        arr = stream[-(n + 1):] if len(stream) >= n + 1 \
            else np.resize(stream, n + 1)
        batch = {"tokens": jnp.asarray(arr[:-1], jnp.int32)[None],
                 "labels": jnp.asarray(arr[1:], jnp.int32)[None]}
        entry = self._deltas.get(req.user)
        vals_dev = jax.tree.map(jnp.asarray, entry.vals)
        idx_dev = jax.tree.map(jnp.asarray, entry.idx)
        t0 = time.perf_counter()
        new_vals, metrics = self._wave(self._trainable, self._frozen,
                                       vals_dev, idx_dev, batch,
                                       self._next_key())
        jax.block_until_ready(new_vals)
        self._wave_s += time.perf_counter() - t0
        self._wave_count += 1
        self._wave_losses.append((req.user, float(metrics["loss"])))
        entry.vals = jax.tree.map(np.asarray, new_vals)
        self._deltas.put(req.user, entry)
        row_tree = None
        for other in sched.live_slots():
            if other is slot or other.request is None or \
                    other.request.user != req.user:
                continue
            if row_tree is None:
                row_tree = self._delta_row_tree(entry)
            self._dbatch = self._insert(self._dbatch, row_tree, other.index)

    # -- input plumbing ----------------------------------------------------

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample_key(self):
        """Greedy sampling ignores the key — skip the per-token split."""
        return self._zero_key if self.temperature <= 0.0 else self._next_key()

    def _chunk_batch(self, req: Request, start: int, size: int):
        """Prefill chunk, always padded to one fixed page-sized shape: the
        final partial chunk would otherwise retrace `paged_step` for every
        distinct prompt-length residue. `length` masks the padding inside
        the step (writes dropped, state frozen, logits at length-1)."""
        ps = self.page_size
        batch = {"start": jnp.asarray([start], jnp.int32),
                 "active": jnp.asarray([True]),
                 "length": jnp.asarray([size], jnp.int32)}
        if self.cfg.embed_inputs:
            emb = np.asarray(req.embeds[start:start + size])
            if size < ps:
                emb = np.pad(emb, ((0, ps - size), (0, 0)))
            batch["embeds"] = jnp.asarray(emb)[None]
        else:
            toks = np.asarray(req.tokens[start:start + size], np.int32)
            if size < ps:
                toks = np.pad(toks, (0, ps - size))
            batch["tokens"] = jnp.asarray(toks)[None]
        return batch

    def _decode_batch(self, tokens_row, pos_row, active_row=None):
        if active_row is None:
            active_row = [True] * self.num_slots
        batch = {"start": jnp.asarray(pos_row, jnp.int32),
                 "active": jnp.asarray(active_row),
                 "length": self._decode_length}
        if self.cfg.embed_inputs:
            # placeholder frontend: fresh embeds each step (fresh key per
            # step — a reused key would feed identical inputs every step)
            batch["embeds"] = jax.random.normal(
                self._next_key(), (self.num_slots, 1, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        else:
            batch["tokens"] = jnp.asarray(tokens_row, jnp.int32)[:, None]
        return batch

    # -- page bookkeeping --------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        if not self.has_pages:
            return 0
        # the final sampled token is returned but never written back
        written = req.prompt_len + req.max_new_tokens - 1
        return -(-written // self.page_size)

    def _worst_case_need(self, slot: Slot) -> int:
        """Pages this live request may still allocate: unallocated logical
        pages plus (at most) one COW of the shared page at its write
        boundary. Full shared prefix pages are never written again, so they
        never COW under the holder."""
        need = sum(1 for pg in range(self._pages_needed(slot.request))
                   if self._pt[slot.index, pg] < 0)
        wp = slot.pos // self.page_size
        if wp < self.max_pages:
            pid = self._pt[slot.index, wp]
            if pid >= 0 and self._pool.ref[pid] > 1:
                need += 1
        return need

    def _headroom(self, sched) -> int:
        avail = self._pool.free_pages
        if self._cache is not None:
            avail += self._cache.evictable()
        return avail - sum(self._worst_case_need(s)
                           for s in sched.live_slots())

    def _evict_until_free(self) -> None:
        while not self._pool.free_pages:
            if self._cache is None or not self._cache.evict_one():
                raise RuntimeError("page pool exhausted with nothing "
                                   "evictable (reservation bug)")

    def _alloc_page(self) -> int:
        self._evict_until_free()
        return self._pool.alloc()

    def _ensure_writable(self, slot: Slot, lo: int, hi: int, pools):
        """Make every page covering token positions [lo, hi) allocated and
        exclusive to `slot`, COW-splitting shared pages (copying their
        device rows) before any write lands in them."""
        if not self.has_pages:
            return pools
        ps = self.page_size
        for pg in range(lo // ps, -(-hi // ps)):
            pid = int(self._pt[slot.index, pg])
            if pid < 0:
                pid = self._alloc_page()
                assert pg == len(slot.page_ids), "non-contiguous page alloc"
                slot.page_ids.append(pid)
                self._pt[slot.index, pg] = pid
            elif self._pool.ref[pid] > 1:
                self._evict_until_free()
                new = self._pool.cow_split(pid)
                pools = self._copy(pools, pid * ps, new * ps)
                slot.page_ids[pg] = new
                self._pt[slot.index, pg] = new
        return pools

    def _page_reader(self, pid: int):
        """Device -> host: one page's token rows from EVERY layer's pool
        (the radix cache's spill callback)."""
        return jax.device_get(
            self._read_rows(self._pools, pid * self.page_size))

    def _page_writer(self, pid: int, blob) -> None:
        """Host -> device: write a spilled page's rows back into EVERY
        layer's pool (the radix cache's rehydrate callback). Grafts the
        blob onto the live pool structure first — disk roundtrips drop
        empty subtrees."""
        blob = _graft_like(self._pools, blob)
        self._pools = self._write_rows(
            self._pools, jax.tree.map(jnp.asarray, blob),
            pid * self.page_size)

    def _release_slot(self, slot: Slot):
        for pid in slot.page_ids:
            self._pool.decref(pid)
        slot.page_ids = []
        slot.registered_pages = 0
        if slot.match is not None:
            if self._cache is not None:
                self._cache.release(slot.match)
            slot.match = None
        self._pt[slot.index, :] = -1

    # -- robustness --------------------------------------------------------

    def _transient_fault(self, slot: Slot) -> bool:
        """Absorb one transient fault on `slot`'s request: count the retry
        and schedule capped exponential backoff. Returns True when the
        retry budget is exhausted — the caller quarantines the request so
        a poison request can never wedge the slot forever."""
        slot.retries += 1
        self._retry_events += 1
        if slot.retries > self.max_retries:
            return True
        back = min(self.retry_backoff_cap_s,
                   self.retry_backoff_s * (2 ** (slot.retries - 1)))
        slot.retry_at = time.perf_counter() + back
        return False

    def _wrap_stream(self, req: Request):
        """Guard a request's stream callback: injected stream faults AND
        real exceptions raised by the callback are absorbed (counted in
        `stream_errors`, treated as "keep generating") — a broken client
        degrades its own stream, it never crashes the engine or changes
        the served tokens. Returning False still cancels."""
        inner = req.stream
        if inner is None or getattr(inner, "_chaos_guarded", False):
            return inner

        def guarded(rid, tok):
            try:
                if self.chaos is not None:
                    self.chaos.maybe_raise(FaultKind.STREAM, site=rid)
                return inner(rid, tok)
            except Exception:
                self._stream_errors += 1
                return None
        guarded._chaos_guarded = True
        return guarded

    def _persist_prefix_state(self) -> None:
        """Flush the radix tree (pages + snapshots) into the host spill
        tier — and, with `prefix_persist`, to disk — while the device
        pools are still alive. Runs on clean exit AND on the crash-unwind
        path, so a killed engine still leaves a warm tree behind."""
        if self.prefix_mode != "radix" or self._cache is None:
            return
        self._cache.spill_all()
        if self._persist_path is not None:
            save_spill_tier(self._persist_path, self._spill,
                            meta={"page_size": self.page_size,
                                  "max_len": self.max_len,
                                  "model": self.cfg.name})

    def recover_requests(self) -> list[Request]:
        """In-flight requests from the journal: admitted by a previous
        (crashed) engine, never completed. Feed them back through `run()`
        — with `prefix_persist` their already-prefilled pages come back
        as prefix/snapshot hits instead of recomputation. Returns [] when
        the engine has no journal."""
        if self._journal is None:
            return []
        return self._journal.pending_requests()

    # -- serve loop --------------------------------------------------------

    def run(self, requests: list[Request], verbose: bool = False) -> ServeStats:
        try:
            return self._run(requests, verbose)
        except BaseException:
            # crash unwind — including InjectedCrash, which `except
            # Exception` recovery code can never swallow: flush the radix
            # tree to the spill tier (and disk, with prefix_persist) so a
            # restarted engine replays journaled requests against a warm
            # prefix cache instead of a cold one
            self._persist_prefix_state()
            raise

    def _run(self, requests: list[Request], verbose: bool) -> ServeStats:
        for r in requests:
            assert r.max_new_tokens >= 1, (
                f"request {r.rid}: max_new_tokens must be >= 1")
            assert r.prompt_len + r.max_new_tokens <= self.max_len, (
                f"request {r.rid}: prompt {r.prompt_len} + gen "
                f"{r.max_new_tokens} exceeds max_len {self.max_len}")
            assert self._pages_needed(r) <= self.num_pages, (
                f"request {r.rid} needs {self._pages_needed(r)} pages; "
                f"pool has {self.num_pages}")
        sched = Scheduler(self.num_slots, eos_id=self.eos_id)
        # rids journaled by a previous (crashed) engine and re-admitted in
        # this run count as journal replays
        replay_rids = (self._journal.pending_rids()
                       if self._journal is not None else set())
        for r in requests:
            r.stream = self._wrap_stream(r)
            sched.submit(r)
        chaos = self.chaos
        faults0 = chaos.faults_injected if chaos is not None else 0
        kinds0 = dict(chaos.faults_by_kind) if chaos is not None else {}
        self._retry_events = 0
        self._stream_errors = 0
        self._watchdog_kills = 0
        self._prefill_s = self._decode_s = 0.0
        self._prefill_tokens = self._decode_tokens = 0
        journal_replays = 0
        shed_rids: set[int] = set()
        mon = StragglerMonitor(factor=self._straggler_factor)

        state, self._pools = D.init_serve_cache(
            self.cfg, self.num_slots, self.max_len,
            max(1, self.num_pages), self.page_size)
        if self.rules is not None:
            # pools shard over KV heads along the model axis; state and the
            # page table stay replicated (page table is a host-side np
            # array, see below). device_put onto the CANONICAL layouts so
            # the very first step call keys the same jit cache entry as
            # steady state.
            from jax.sharding import NamedSharding
            shard = NamedSharding(self.rules.mesh, D.pool_pspec(self.rules))
            self._pools = jax.tree.map(
                lambda a: jax.device_put(a, shard), self._pools)
            state = jax.tree.map(
                lambda a: jax.device_put(a, self._state_shard), state)
        self._pool_bytes = sum(a.size * a.dtype.itemsize
                               for a in jax.tree.leaves(self._pools))
        self._pt = np.full((self.num_slots, self.max_pages), -1, np.int32)
        self._pool = PagePool(max(1, self.num_pages), self.page_size,
                              chaos=self.chaos)
        if self.prefix_mode == "radix":
            self._cache = RadixPrefixCache(
                self._pool, has_pages=self.has_pages,
                reader=self._page_reader if self.has_pages else None,
                writer=self._page_writer if self.has_pages else None,
                spill=self._spill, snapshot_budget=self.snapshot_budget,
                max_nodes=self.max_tree_nodes)
        elif self.prefix_mode == "chain":
            self._cache = ChainPrefixCache(self._pool)
        else:
            self._cache = None
        if self._p13n is not None:
            self._dbatch = self._delta_batch_zeros()
            if self.rules is not None:
                self._dbatch = jax.tree.map(
                    lambda a: jax.device_put(a, self._state_shard),
                    self._dbatch)
            self._duser = [None] * self.num_slots
            self._wave_s, self._wave_count = 0.0, 0
            self._wave_losses = []
        prefill_chunks = 0
        results: dict[int, RequestResult] = {}
        t0 = time.perf_counter()
        deadline = {r.rid: (t0 + r.timeout_s if r.timeout_s is not None
                            else None) for r in requests}

        def close(slot, status):
            req = slot.request
            if self._p13n is not None and req.user is not None:
                if status == "completed" and req.tokens is not None:
                    self._online_wave(slot, sched)
                self._deltas.release(req.user)
            results[req.rid] = RequestResult(
                req.rid, list(slot.out_tokens),
                time.perf_counter() - t0, status)
            self._release_slot(slot)
            if self._journal is not None:
                self._journal.done(req.rid, status)
            # injected crash fires AFTER the journal records this request
            # done and its slot is released: the completed request is never
            # replayed, and pool accounting stays consistent for the
            # crash-unwind prefix flush
            if chaos is not None and status == "completed" \
                    and chaos.crash_due(sched.requests_completed):
                raise InjectedCrash(
                    f"injected crash after {sched.requests_completed} "
                    f"completed request(s)")
            if verbose and status == "completed":
                print(f"[serve] completed {sched.requests_completed}"
                      f"/{len(requests)} requests")

        it_prev, it_work = None, False
        while not sched.done:
            now = time.perf_counter()
            # per-wave serve timing: only iterations that ran a jitted step
            # count — idle/backoff spins are sub-ms and would drag the
            # median down until every real wave looked like a straggler
            if it_prev is not None and it_work:
                mon.record(now - it_prev)
            it_prev, it_work = now, False
            # 1) deadlines: cancel overdue slots, drop overdue queued
            # requests; watchdog-quarantine slots that stopped progressing
            for slot in sched.live_slots():
                dl = deadline[slot.request.rid]
                if dl is not None and now > dl:
                    sched.cancel(slot)
                    close(slot, "cancelled")
                    continue
                if (self.watchdog_s is not None
                        and now - slot.last_progress > self.watchdog_s):
                    self._watchdog_kills += 1
                    sched.quarantine(slot)
                    close(slot, "quarantined")
            for req in [q for q in sched.queue
                        if deadline[q.rid] is not None
                        and now > deadline[q.rid]]:
                sched.drop_queued(req)
                results[req.rid] = RequestResult(req.rid, [], 0.0, "cancelled")

            # 2) admission (two-phase: page-pool pressure can defer the
            # queue head without disturbing FIFO order)
            while (adm := sched.peek_admission()) is not None:
                slot, req = adm
                mr, matched, covered = None, [], 0
                # personalized requests compute K/V under their own delta:
                # sharing those pages (or adopting shared ones) would serve
                # another user's prefix from the wrong weights
                if self._cache is not None and req.tokens is not None \
                        and req.user is None:
                    # leave >= 1 prompt token uncached: something must
                    # produce the logits that sample the first token
                    mr = self._cache.match(
                        np.asarray(req.tokens), req.prompt_len - 1,
                        need_state=self._need_state)
                    matched, covered = mr.pages, mr.covered
                has_partial = bool(matched) and matched[-1][1] < self.page_size
                need = self._pages_needed(req) - len(matched) + int(has_partial)
                pressure = self.has_pages and self._headroom(sched) < need
                # load shedding: admitting would drop free pages below the
                # watermark, so defer while anything is in flight. The shed
                # request stays queued, keeps ticking toward its own
                # timeout_s (the queued-deadline drop above provides the
                # accounting) — never silently dropped.
                shed = (not pressure and self.has_pages
                        and self.shed_watermark > 0.0
                        and bool(sched.live_slots())
                        and self._headroom(sched) - need
                        < self.shed_watermark * self.num_pages)
                if pressure or shed:
                    if mr is not None:              # roll the match back
                        self._cache.abandon(mr, req.prompt_len)
                        mr, matched, covered = None, [], 0
                    if sched.live_slots():
                        if shed:
                            shed_rids.add(req.rid)  # counted once per rid
                        break       # retry when an in-flight request frees pages
                    # nothing in flight will ever free pages: admit WITHOUT
                    # sharing — with no live slots every cache page is
                    # evictable, so pages_needed <= num_pages always fits
                    # (the watermark never blocks this path: degraded
                    # trickle admission beats deadlock)
                    assert self._headroom(sched) >= self._pages_needed(req)
                sched.commit_admission(slot, prefilled=covered)
                slot.last_progress = time.perf_counter()
                if self._journal is not None:
                    if req.rid in replay_rids:
                        journal_replays += 1
                        replay_rids.discard(req.rid)
                    self._journal.admit(req)
                slot.match = mr     # pinned until the slot closes
                slot.page_ids = [pid for pid, _ in matched]
                slot.registered_pages = len(matched) - int(has_partial)
                self._pt[slot.index, :] = -1
                self._pt[slot.index, :len(matched)] = slot.page_ids
                if mr is not None and mr.snapshot is not None:
                    # restore the recurrent state at the matched boundary;
                    # prefill resumes from slot.pos = covered
                    blob = _graft_like(state, mr.snapshot)
                    state = self._insert(
                        state, jax.tree.map(jnp.asarray, blob), slot.index)
                else:
                    state = self._reset(state, slot.index)
                if self._p13n is not None:
                    if req.user is not None:
                        entry = self._deltas.admit(req.user)
                        self._dbatch = self._insert(
                            self._dbatch, self._delta_row_tree(entry),
                            slot.index)
                        self._duser[slot.index] = req.user
                    elif self._duser[slot.index] is not None:
                        # recycle a slot a personalized request left dirty
                        self._dbatch = self._reset(self._dbatch, slot.index)
                        self._duser[slot.index] = None

            # 3) chunked prefill: one page-sized chunk per PREFILL slot
            for slot in sched.prefill_slots():
                req = slot.request
                if now < slot.retry_at:
                    continue        # backing off after a transient fault
                # faults are injected BEFORE the jitted step and before any
                # pool mutation, so absorbing one and retrying next
                # iteration replays the identical chunk — injected faults
                # can delay a request but never change its tokens
                try:
                    if chaos is not None:
                        chaos.maybe_raise(FaultKind.STEP, site=req.rid)
                        if chaos.draw(FaultKind.SLOW, site=req.rid):
                            time.sleep(chaos.slow_s)
                    shareable = (self._cache is not None
                                 and req.tokens is not None
                                 and req.user is None)
                    # chunk-time adoption: a page a CONCURRENT slot
                    # registered since our admission can be attached
                    # instead of recomputed (same-wave admissions of a
                    # common prefix share this way). State archs skip it:
                    # adopting K/V rows without restoring the recurrent
                    # state at that boundary would skip the state those
                    # tokens should have produced.
                    while (shareable and not self._need_state
                           and slot.pos % self.page_size == 0
                           and slot.pos + self.page_size <= req.prompt_len - 1
                           and slot.pos // self.page_size == len(slot.page_ids)):
                        pid = self._cache.match_page(
                            np.asarray(req.tokens), slot.pos)
                        if pid is None:
                            break
                        slot.page_ids.append(pid)
                        self._pt[slot.index, len(slot.page_ids) - 1] = pid
                        slot.pos += self.page_size
                        slot.registered_pages = len(slot.page_ids)
                    size = min(self.page_size, req.prompt_len - slot.pos)
                    self._pools = self._ensure_writable(
                        slot, slot.pos, slot.pos + size, self._pools)
                    st_row = self._extract(state, slot.index)
                    pt_row = jnp.asarray(self._pt[slot.index:slot.index + 1])
                    d_row = None if self._dbatch is None else \
                        self._extract(self._dbatch, slot.index)
                    ts = time.perf_counter()
                    logits, st_row, self._pools = self._step(
                        self.params, self._chunk_batch(req, slot.pos, size),
                        st_row, self._pools, pt_row, d_row)
                    jax.block_until_ready(logits)
                    self._prefill_s += time.perf_counter() - ts
                    self._prefill_tokens += size
                    state = self._insert(state, st_row, slot.index)
                    slot.pos += size
                    prefill_chunks += 1
                    it_work = True
                    slot.last_progress = time.perf_counter()
                    if shareable and self.has_pages:
                        slot.registered_pages = self._cache.insert_pages(
                            np.asarray(req.tokens),
                            min(slot.pos, req.prompt_len) // self.page_size,
                            slot.page_ids, slot.registered_pages)
                    if (shareable and self._need_state and slot.pos > 0
                            and slot.pos % self.page_size == 0
                            and self._cache.wants_snapshot(
                                np.asarray(req.tokens), slot.pos)):
                        # recurrent state at this page boundary, copied to
                        # host: the snapshot that lets a later
                        # shared-prefix request resume from here instead
                        # of re-prefilling
                        blob = jax.tree.map(
                            np.asarray,
                            jax.device_get(self._extract(state, slot.index)))
                        self._cache.insert_snapshot(
                            np.asarray(req.tokens), slot.pos, blob)
                    if slot.pos == req.prompt_len:
                        sched.finish_prefill(slot)
                        if shareable and self.has_pages \
                                and not self._need_state \
                                and self._headroom(sched) >= 1:
                            self._cache.insert_partial(
                                np.asarray(req.tokens), slot.page_ids[-1])
                        first = int(
                            self._sample(logits, self._sample_key())[0])
                        outcome = sched.record_token(slot, first)
                        if outcome is not None:
                            close(slot, "completed" if outcome == "done"
                                  else "cancelled")
                except InjectedFault:
                    # partial progress before the fault (adopted pages,
                    # incremental allocs) is recorded on the slot, so the
                    # retry resumes consistently instead of re-doing it
                    if self._transient_fault(slot):
                        sched.quarantine(slot)
                        close(slot, "quarantined")

            active = sched.active_slots()
            if not active:
                if not sched.prefill_slots() and sched.queue:
                    # nothing live and the admission loop still left the
                    # queue untouched: the forced unshared-admission path
                    # guarantees this is unreachable unless accounting broke
                    raise RuntimeError(
                        "serve deadlock: queued requests but no admissible "
                        "slot (page-pool accounting bug)")
                continue

            # 4) one decode step over the full fixed-shape batch; each slot
            # consumes its last sampled token at position slot.pos. Slots
            # backing off after a transient fault — or drawing one now —
            # are masked out of active_row: an inactive row keeps its state
            # and cache bit-for-bit (existing engine contract), so the
            # retried step feeds identical inputs and, with greedy
            # sampling's fixed key, produces the identical token.
            runnable = []
            for slot in active:
                if now < slot.retry_at:
                    continue
                try:
                    if chaos is not None:
                        chaos.maybe_raise(FaultKind.STEP,
                                          site=slot.request.rid)
                        if chaos.draw(FaultKind.SLOW, site=slot.request.rid):
                            time.sleep(chaos.slow_s)
                    self._pools = self._ensure_writable(
                        slot, slot.pos, slot.pos + 1, self._pools)
                except InjectedFault:
                    if self._transient_fault(slot):
                        sched.quarantine(slot)
                        close(slot, "quarantined")
                    continue
                runnable.append(slot)
            if not runnable:
                time.sleep(0.0005)  # everyone backing off: don't busy-spin
                continue
            run_idx = {s.index for s in runnable}
            tokens_row = [s.last_token for s in sched.slots]
            pos_row = [min(s.pos, self.max_len - 1) for s in sched.slots]
            active_row = [s.state is SlotState.ACTIVE and s.index in run_idx
                          for s in sched.slots]
            ts = time.perf_counter()
            logits, state, self._pools = self._step(
                self.params,
                self._decode_batch(tokens_row, pos_row, active_row),
                state, self._pools, jnp.asarray(self._pt), self._dbatch)
            jax.block_until_ready(logits)
            self._decode_s += time.perf_counter() - ts
            self._decode_tokens += len(runnable)
            it_work = True
            toks = np.asarray(self._sample(logits, self._sample_key()))
            for slot in runnable:         # inactive rows: sampled, discarded
                slot.pos += 1             # the fed token is now cached
                slot.last_progress = time.perf_counter()
                outcome = sched.record_token(slot, int(toks[slot.index]))
                if outcome is not None:
                    close(slot, "completed" if outcome == "done"
                          else "cancelled")

        self._persist_prefix_state()
        wall = time.perf_counter() - t0
        lat = [r.latency_s for r in results.values()
               if r.status == "completed"] or [0.0]
        c = self._cache
        if chaos is not None:
            d_faults = chaos.faults_injected - faults0
            d_kinds = {k: v - kinds0.get(k, 0)
                       for k, v in chaos.faults_by_kind.items()
                       if v - kinds0.get(k, 0)}
        else:
            d_faults, d_kinds = 0, {}
        return ServeStats(
            requests_completed=sched.requests_completed,
            requests_cancelled=sched.requests_cancelled,
            tokens_out=sched.tokens_out,
            tokens_cancelled=sched.tokens_cancelled,
            wall_s=wall,
            tok_per_s=sched.tokens_out / max(wall, 1e-9),
            latency_p50_s=float(np.percentile(lat, 50)),
            latency_p95_s=float(np.percentile(lat, 95)),
            refills=sched.refills,
            prefill_chunks=prefill_chunks,
            prefix_hit_tokens=(c.hit_tokens if c is not None else 0),
            prefix_lookup_tokens=(c.lookup_tokens if c is not None else 0),
            pages_total=self.num_pages,
            pages_peak=self._pool.peak_in_use,
            cow_splits=self._pool.cow_splits,
            results=results,
            prefix_mode=self.prefix_mode,
            prefix_lookups=(c.lookups if c is not None else 0),
            state_lookups=(c.state_lookups if c is not None else 0),
            radix_nodes=(c.node_count if c is not None else 0),
            snapshot_hits=(c.snapshot_hits if c is not None else 0),
            snapshots_stored=(c.snapshots_stored if c is not None else 0),
            spills=(c.spills if c is not None else 0),
            rehydrates=(c.rehydrates if c is not None else 0),
            spill_entries=(len(self._spill) if self._spill is not None else 0),
            delta_hits=(self._deltas.hits if self._p13n is not None else 0),
            delta_lookups=(self._deltas.hits + self._deltas.misses
                           if self._p13n is not None else 0),
            delta_evictions=(self._deltas.evictions
                             if self._p13n is not None else 0),
            delta_resident_bytes=(self._deltas.resident_bytes
                                  if self._p13n is not None else 0),
            train_waves=(self._wave_count if self._p13n is not None else 0),
            train_wave_s=(self._wave_s if self._p13n is not None else 0.0),
            wave_losses=(list(self._wave_losses)
                         if self._p13n is not None else []),
            faults_injected=d_faults,
            faults_by_kind=d_kinds,
            retries=self._retry_events,
            sheds=len(shed_rids),
            quarantined=sched.requests_quarantined,
            tokens_quarantined=sched.tokens_quarantined,
            watchdog_kills=self._watchdog_kills,
            stream_errors=self._stream_errors,
            journal_replays=journal_replays,
            stragglers=len(mon.flagged),
            mesh_shards=self.mesh_shards,
            pool_shard_bytes=self._pool_bytes // max(1, self.mesh_shards),
            prefill_s=self._prefill_s,
            decode_s=self._decode_s,
            prefill_tokens=self._prefill_tokens,
            decode_tokens=self._decode_tokens,
        )


def make_random_requests(cfg, n: int, prompt_len: int, gen_len: int,
                         seed: int = 0, **req_kw) -> list[Request]:
    """Uniform-random prompts (token ids, or embeds for embed-input
    frontends) — the synthetic serving workload."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        if cfg.embed_inputs:
            emb = rng.standard_normal(
                (prompt_len, cfg.d_model)).astype(np.float32)
            reqs.append(Request(rid, gen_len, embeds=emb, **req_kw))
        else:
            toks = rng.integers(
                0, cfg.vocab_size, prompt_len).astype(np.int32)
            reqs.append(Request(rid, gen_len, tokens=toks, **req_kw))
    return reqs


def make_shared_prefix_requests(cfg, n: int, prefix_len: int, prompt_len: int,
                                gen_len: int, seed: int = 0) -> list[Request]:
    """Workload with a common `prefix_len`-token prompt prefix (system-
    prompt style): later admissions hit the prefix cache and share pages."""
    assert 0 < prefix_len <= prompt_len
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    reqs = []
    for rid in range(n):
        tail = rng.integers(
            0, cfg.vocab_size, prompt_len - prefix_len).astype(np.int32)
        reqs.append(Request(rid, gen_len,
                            tokens=np.concatenate([prefix, tail])))
    return reqs


def make_branching_prefix_requests(cfg, n: int, prompt_len: int, gen_len: int,
                                   *, page_size: int = 16,
                                   max_prefix_pages: int = 4, branch: int = 2,
                                   zipf_a: float = 1.5,
                                   seed: int = 0) -> list[Request]:
    """Partially-overlapping prefix workload: prompts walk a `branch`-ary
    token tree with zipf-skewed branch popularity (few-shot preambles that
    agree for a while, then diverge), so pairs of requests share SOME page-
    aligned prefix but rarely the whole prompt. This is the workload where
    the radix tree's arbitrary-prefix matching beats whole-chain hashing.
    Page content at each tree position is keyed by the path to it, so equal
    paths yield identical tokens across requests (and across runs)."""
    assert prompt_len > max_prefix_pages * page_size
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, branch + 1) ** zipf_a
    w /= w.sum()
    reqs = []
    for rid in range(n):
        depth = 1 + int(rng.integers(0, max_prefix_pages))
        path: list[int] = []
        pages = []
        for _ in range(depth):
            path.append(int(rng.choice(branch, p=w)))
            pages.append(np.random.default_rng([seed, *path]).integers(
                0, cfg.vocab_size, page_size).astype(np.int32))
        prefix = np.concatenate(pages)
        tail = rng.integers(0, cfg.vocab_size,
                            prompt_len - len(prefix)).astype(np.int32)
        reqs.append(Request(rid, gen_len,
                            tokens=np.concatenate([prefix, tail])))
    return reqs
