"""Continuous-batching engine: fixed decode slots over the stacked caches.

One engine iteration:

1. *Refill*: while a FREE slot and a queued request exist, run a batch=1
   prefill of the request (jitted, padded to ``max_len``), sample its first
   token, and splice the resulting cache row into the live batch cache with
   ``decoding.cache_insert_row`` — the other slots are untouched and the
   batch is never drained.
2. *Decode*: one jitted fixed-shape ``decoding.decode_step`` over all slots
   with per-slot positions, then one sampling call. Tokens landing on FREE
   slots are discarded; only ACTIVE slots are recorded/accounted.

PRNG: the engine key is split every step, so temperature sampling and the
placeholder-embeds input path (``cfg.embed_inputs`` frontends) never reuse a
key across steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decoding as D
from repro.serve.sampling import sample_token
from repro.serve.scheduler import Request, Scheduler

__all__ = ["RequestResult", "ServeEngine", "ServeStats",
           "make_random_requests"]


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: list            # sampled token ids, in order
    latency_s: float        # submit -> completion (includes queueing)


@dataclasses.dataclass
class ServeStats:
    requests_completed: int
    tokens_out: int
    wall_s: float
    tok_per_s: float
    latency_p50_s: float
    latency_p95_s: float
    refills: int            # admissions that recycled a dirty slot
    results: dict           # rid -> RequestResult


class ServeEngine:
    """Continuous-batching serve loop for one model + parameter set."""

    def __init__(self, cfg, params, *, num_slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0):
        assert num_slots >= 1 and max_len >= 2
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.temperature = float(temperature)
        self.eos_id = eos_id
        self._key = jax.random.PRNGKey(seed)
        self._zero_key = jax.random.PRNGKey(0)

        self._prefill = jax.jit(
            lambda p, batch: D.prefill(cfg, p, batch, pad_to=max_len))
        self._decode = jax.jit(
            lambda p, batch, cache: D.decode_step(cfg, p, batch, cache))
        self._insert = jax.jit(D.cache_insert_row)
        self._sample = jax.jit(
            lambda logits, key: sample_token(logits, key, self.temperature))

    # -- input plumbing ----------------------------------------------------

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample_key(self):
        """Greedy sampling ignores the key — skip the per-token split."""
        return self._zero_key if self.temperature <= 0.0 else self._next_key()

    def _positions(self, pos_row):
        positions = jnp.asarray(pos_row, jnp.int32)[:, None]      # [B, 1]
        if self.cfg.mrope:
            positions = jnp.broadcast_to(
                positions, (3,) + positions.shape)                # [3, B, 1]
        return positions

    def _prefill_batch(self, req: Request):
        batch = {}
        if self.cfg.embed_inputs:
            batch["embeds"] = jnp.asarray(req.embeds)[None]
        else:
            batch["tokens"] = jnp.asarray(req.tokens, jnp.int32)[None]
        if self.cfg.mrope:
            pos = jnp.arange(req.prompt_len, dtype=jnp.int32)[None]
            batch["positions"] = jnp.broadcast_to(
                pos, (3, 1, req.prompt_len))
        return batch

    def _decode_batch(self, tokens_row, pos_row):
        batch = {"positions": self._positions(pos_row)}
        if self.cfg.embed_inputs:
            # placeholder frontend: fresh embeds each step (fresh key per
            # step — a reused key would feed identical inputs every step)
            batch["embeds"] = jax.random.normal(
                self._next_key(), (self.num_slots, 1, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        else:
            batch["tokens"] = jnp.asarray(tokens_row, jnp.int32)[:, None]
        return batch

    # -- serve loop --------------------------------------------------------

    def run(self, requests: list[Request], verbose: bool = False) -> ServeStats:
        for r in requests:
            assert r.max_new_tokens >= 1, (
                f"request {r.rid}: max_new_tokens must be >= 1")
            assert r.prompt_len + r.max_new_tokens <= self.max_len, (
                f"request {r.rid}: prompt {r.prompt_len} + gen "
                f"{r.max_new_tokens} exceeds max_len {self.max_len}")
        sched = Scheduler(self.num_slots, eos_id=self.eos_id)
        for r in requests:
            sched.submit(r)

        cache = D.init_cache(self.cfg, self.num_slots, self.max_len)
        results: dict[int, RequestResult] = {}
        t0 = time.perf_counter()

        def finish(slot):
            results[slot.request.rid] = RequestResult(
                slot.request.rid, list(slot.out_tokens),
                time.perf_counter() - t0)
            if verbose:
                print(f"[serve] completed {sched.requests_completed}"
                      f"/{len(requests)} requests")

        while not sched.done:
            # 1) refill every free slot from the queue (per-slot admission)
            while (adm := sched.next_admission()) is not None:
                slot, req = adm
                logits, row_cache = self._prefill(
                    self.params, self._prefill_batch(req))
                cache = self._insert(cache, row_cache, slot.index)
                first = int(self._sample(logits, self._sample_key())[0])
                if sched.record_token(slot, first):
                    finish(slot)

            active = sched.active_slots()
            if not active:
                continue    # everything admitted finished at prefill

            # 2) one decode step over the full fixed-shape batch; each slot
            # consumes its last sampled token at position slot.pos
            tokens_row = [s.last_token for s in sched.slots]
            pos_row = [min(s.pos, self.max_len - 1) for s in sched.slots]
            logits, cache = self._decode(
                self.params, self._decode_batch(tokens_row, pos_row), cache)
            toks = np.asarray(self._sample(logits, self._sample_key()))
            for slot in active:           # FREE rows: sampled but discarded
                slot.pos += 1             # the fed token is now cached
                if sched.record_token(slot, int(toks[slot.index])):
                    finish(slot)

        wall = time.perf_counter() - t0
        lat = [r.latency_s for r in results.values()] or [0.0]
        return ServeStats(
            requests_completed=sched.requests_completed,
            tokens_out=sched.tokens_out,
            wall_s=wall,
            tok_per_s=sched.tokens_out / max(wall, 1e-9),
            latency_p50_s=float(np.percentile(lat, 50)),
            latency_p95_s=float(np.percentile(lat, 95)),
            refills=sched.refills,
            results=results,
        )


def make_random_requests(cfg, n: int, prompt_len: int, gen_len: int,
                         seed: int = 0) -> list[Request]:
    """Uniform-random prompts (token ids, or embeds for embed-input
    frontends) — the synthetic serving workload."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        if cfg.embed_inputs:
            emb = rng.standard_normal(
                (prompt_len, cfg.d_model)).astype(np.float32)
            reqs.append(Request(rid, gen_len, embeds=emb))
        else:
            toks = rng.integers(
                0, cfg.vocab_size, prompt_len).astype(np.int32)
            reqs.append(Request(rid, gen_len, tokens=toks))
    return reqs
