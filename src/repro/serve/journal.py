"""Request-lifecycle journal: crash-safe re-admission for the serve engine.

Append-only JSONL, fsynced at the two lifecycle edges that matter for
restart correctness:

- ``admit``  — written when a request is bound to a slot. Records
  everything needed to rebuild the request after a crash: rid, prompt
  tokens, max_new_tokens, user. (Embed-input requests have no replayable
  token identity and are skipped with a warning.)
- ``done``   — written when the slot closes, whatever the terminal status
  (completed / cancelled / quarantined).

A request with an ``admit`` record and no ``done`` record was *in flight*
when the process died; ``pending_requests()`` rebuilds those as fresh
``Request`` objects for idempotent re-admission — the restarted engine
serves them through the persisted prefix spill tier (``--prefix-persist``)
so their already-prefilled pages come back as prefix hits instead of
recomputation. Replaying is rid-keyed: a re-admitted request writes a new
``admit`` record, and its eventual ``done`` clears it, so a second restart
replays only what is still genuinely unfinished.

Torn-tail tolerance: each line carries a crc32 of its payload. A crash
mid-append leaves at most one torn final line; replay verifies every
line's checksum and skips (with a warning) anything that fails to parse —
a torn journal tail can never poison recovery.

Format (one JSON object per line)::

    {"v": {"e": "admit", "rid": 3, "tokens": [...], "gen": 16,
           "user": null, "t": 1754650000.0}, "c": 2186037955}
    {"v": {"e": "done", "rid": 3, "status": "completed"}, "c": 1975521151}
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Optional

import numpy as np

__all__ = ["RequestJournal"]


def _crc(payload: dict) -> int:
    import zlib
    return zlib.crc32(json.dumps(payload, sort_keys=True,
                                 separators=(",", ":")).encode())


class RequestJournal:
    """Append-only, fsynced request-lifecycle journal (see module doc)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "ab")
        self.records_written = 0
        self.torn_lines_skipped = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- write path --------------------------------------------------------

    def _append(self, payload: dict) -> None:
        line = json.dumps({"v": payload, "c": _crc(payload)},
                          separators=(",", ":"))
        self._f.write(line.encode() + b"\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        self.records_written += 1

    def admit(self, req) -> bool:
        """Journal a request at admission time. Returns False (and warns,
        once per journal) for embed-input requests, which have no token
        stream to replay."""
        if req.tokens is None:
            if not getattr(self, "_warned_embeds", False):
                self._warned_embeds = True
                warnings.warn("request journal: embed-input requests are "
                              "not replayable; skipping")
            return False
        self._append({"e": "admit", "rid": int(req.rid),
                      "tokens": [int(t) for t in np.asarray(req.tokens)],
                      "gen": int(req.max_new_tokens),
                      "user": req.user if isinstance(req.user, (int, str))
                      else (None if req.user is None else str(req.user))})
        return True

    def done(self, rid: int, status: str) -> None:
        self._append({"e": "done", "rid": int(rid), "status": status})

    # -- replay path -------------------------------------------------------

    def _scan(self) -> dict[int, dict]:
        """Read the file back: rid -> latest un-done admit payload.
        Checksum-failing / unparseable lines are counted and skipped."""
        pending: dict[int, dict] = {}
        if not os.path.exists(self.path):
            return pending
        with open(self.path, "rb") as f:
            for raw in f:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    rec = json.loads(raw)
                    payload = rec["v"]
                    if rec["c"] != _crc(payload):
                        raise ValueError("checksum mismatch")
                except (ValueError, KeyError, TypeError):
                    self.torn_lines_skipped += 1
                    warnings.warn("request journal: skipping torn/corrupt "
                                  "line (crash mid-append)")
                    continue
                if payload["e"] == "admit":
                    pending[payload["rid"]] = payload
                elif payload["e"] == "done":
                    pending.pop(payload["rid"], None)
        return pending

    def pending_rids(self) -> set[int]:
        return set(self._scan())

    def pending_requests(self) -> list:
        """In-flight requests (admitted, never done), rebuilt as fresh
        ``Request`` objects in admission order. Stream callbacks and
        timeouts are process-local and do not survive the crash."""
        from repro.serve.scheduler import Request
        out = []
        for rid, p in sorted(self._scan().items()):
            out.append(Request(rid, p["gen"],
                               tokens=np.asarray(p["tokens"], np.int32),
                               user=p.get("user")))
        return out
