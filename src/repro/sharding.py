"""Logical-axis sharding rules (MaxText-style) + ambient mesh context.

Models annotate activations/params with *logical* axis names; the trainer
installs a rule set mapping logical names -> mesh axes. With no rules
installed (CPU unit tests) everything is a no-op, so model code is
mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary used across the model zoo, and where each axis
# lands on the mesh. Training resolves these through `constrain` (GSPMD);
# serving slices params explicitly (`paged_param_specs` + shard_map), where
# the same names map onto the "model" axis as column-/row-parallel weights:
#
#   batch      - global batch                  -> ("pod", "data"); serve: replicated
#   seq        - sequence (activations)        -> None (or "data" for long decode cache)
#   cache_seq  - kv-cache sequence             -> None / "data" for long_500k
#   model_d    - d_model embed dim             -> None (activations replicated;
#                row-parallel matmuls psum back into it)
#   heads      - attention query heads         -> "model" (serve: col-parallel wq)
#   kv_heads   - attention kv heads            -> "model" (serve: col wk/wv, row wo)
#   ff         - FFN hidden                    -> "model" (serve: col gate/up, row down;
#                also MoE expert FFNs and the rwkv channel-mix)
#   vocab      - vocabulary                    -> "model" (serve: vocab-parallel embed
#                gather + local-vocab LM-head logits)
#   expert     - MoE expert                    -> "model" (train: expert-parallel
#                dispatch; serve: experts all resident, their d_ff sharded instead)
#   layers     - stacked-layer leading axis    -> None
#   d_inner    - mamba/rwkv inner channels     -> "model" (serve: conv + ssm scan and
#                the rwkv wkv state run on the local channel/head shard)
#   paged_pool - serve page-pool KV-head axis  -> "model"
#   page_table - per-slot page tables          -> None (replicated host state)
#
# Serve-time fallback: a dim that does not divide the model-axis size stays
# replicated for that leaf group only — e.g. rwkv6 time-mix [d, d] mats need
# H % shards == 0 because the wkv scan is head-local, so a partial head
# cannot straddle shards. `col_matmul`/`row_matmul` detect a replicated
# weight by its local shape and skip their collective, so the replication
# audit's allowlist and the executed math agree by construction.

_STATE = threading.local()


class AxisRules:
    def __init__(self, rules: dict[str, Optional[tuple[str, ...] | str]],
                 mesh: Optional[Mesh] = None,
                 batch_axes: tuple[str, ...] = (),
                 model_axis: Optional[str] = None):
        self.rules = rules
        self.mesh = mesh
        self.batch_axes = batch_axes   # mesh axes carrying data parallelism
        self.model_axis = model_axis   # mesh axis carrying tensor/expert parallelism

    def resolve(self, *logical: Optional[str]) -> P:
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            else:
                out.append(self.rules.get(name))
        return P(*out)


def default_rules(mesh: Mesh) -> AxisRules:
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    rules = {
        "batch": batch_axes or None,
        "seq": None,
        "cache_seq": None,
        "model_d": None,
        "heads": model_axis,
        "kv_heads": model_axis,
        "ff": model_axis,
        "vocab": model_axis,
        "expert": model_axis,
        "layers": None,
        "d_inner": model_axis,
        "sel": None,
        "paged_pool": model_axis,
        "page_table": None,
    }
    return AxisRules(rules, mesh=mesh, batch_axes=batch_axes, model_axis=model_axis)


def seq_sharded_rules(mesh: Mesh) -> AxisRules:
    """Rules for long-context decode: KV cache sequence sharded over data
    (batch too small to shard). Used by long_500k."""
    r = default_rules(mesh)
    rules = dict(r.rules)
    rules["cache_seq"] = r.batch_axes or None
    rules["batch"] = None
    return AxisRules(rules, mesh=mesh, batch_axes=r.batch_axes,
                     model_axis=r.model_axis)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


def logical_spec(*logical: Optional[str]) -> P:
    r = current_rules()
    if r is None:
        return P()
    return r.resolve(*logical)


def constrain(x, *logical: Optional[str]):
    """Apply a sharding constraint by logical axes; identity with no rules."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.resolve(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    return NamedSharding(r.mesh, r.resolve(*logical))


def model_axis_size() -> int:
    r = current_rules()
    if r is None or r.model_axis is None:
        return 1
    if r.mesh is None:
        # Rules that name a model axis but carry no mesh used to fall back
        # to 1 here, silently desyncing sharded pool shapes from their
        # replicated page tables. Refuse instead.
        raise ValueError(
            "AxisRules name a model axis "
            f"({r.model_axis!r}) but carry no mesh; model_axis_size() "
            "cannot be resolved. Install rules built from a mesh "
            "(e.g. default_rules(mesh)).")
    return r.mesh.shape[r.model_axis]


@contextlib.contextmanager
def mapped_model_axis(name: Optional[str]):
    """Mark that model code is tracing INSIDE a shard_map over mesh axis
    `name`: arrays are per-shard locals there, so `constrain` rules do not
    apply and row-sharded matmul partials need an explicit psum
    (`psum_mapped`)."""
    prev = getattr(_STATE, "mapped_axis", None)
    _STATE.mapped_axis = name
    try:
        yield
    finally:
        _STATE.mapped_axis = prev


def current_mapped_axis() -> Optional[str]:
    return getattr(_STATE, "mapped_axis", None)


def psum_mapped(x):
    """Sum partial matmul results over the mapped model axis; identity
    outside a shard_map (where GSPMD inserts its own collectives)."""
    ax = current_mapped_axis()
    return x if ax is None else jax.lax.psum(x, ax)


def all_gather_mapped(x, axis: int):
    """Concatenate per-shard slices along `axis` over the mapped model axis
    (tiled all_gather, shard order); identity outside a shard_map. Used to
    reassemble replicated cache state (rwkv wkv heads, mamba channels, ring
    KV heads) before it leaves the shard_map body."""
    ax = current_mapped_axis()
    return x if ax is None else jax.lax.all_gather(x, ax, axis=axis, tiled=True)


def spec_tree_to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
