"""Pallas TPU kernel: expert-batched block-sparse weight-gradient matmul.

The MoE expert analogue of `masked_dw`: dW is computed ONLY for selected
output-channel blocks, for EVERY expert of a stacked expert leaf, in ONE
`pallas_call`. PR 3 certified the dense-layer compact train step at a
constant launch count per leaf, but the expert path still ran a per-expert
jnp einsum backward (a ROADMAP Kernels open item); this kernel closes it —
the grid spans experts as well as TP shards and selected blocks, and the
scalar-prefetched [n_shards, n_sel] index table routes the dY BlockSpec to
`shard_base + idx[s, j]` exactly as in the 2D kernel (the selection is
shared across experts: the framework selects per weight, not per expert).

    x:   [E, C, K]          per-expert activation buffers (capacity C)
    dy:  [E, C, N]          upstream gradient (N = n_shards * n_blocks * block)
    idx: [n_shards, n_sel]  selected block indices, local to each shard
    out: [E, K, n_shards, n_sel, block]   compact dW (fp32)

Grid: (E, n_shards, n_sel, K/TK, C/TM); C is the contraction ("arbitrary")
innermost dimension, accumulated into a VMEM scratch across grid steps.
Unselected blocks are never read, computed, or written.

`batched_dw_pipelined_kernel` is the double-buffered variant (the other
ROADMAP Kernels open item): x and dy stay in HBM (`memory_space=ANY`) and a
`pltpu.emit_pipeline` inner grid streams the C tiles through VMEM with
explicit double buffering, so VMEM residency is bounded by two tiles per
operand plus the [TK, block] accumulator no matter how large C grows —
select it when a whole [C, TK] stripe stops fitting VMEM (`kernels.ops`
holds the policy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import ensure_pipeline_emulation, pallas_compiler_params


def _kernel(idx_ref, x_ref, dy_ref, out_ref, acc_ref, *, n_m: int):
    mi = pl.program_id(4)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)        # [TM, TK]
    dy = dy_ref[0].astype(jnp.float32)      # [TM, block]
    acc_ref[...] += jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [TK, block]

    @pl.when(mi == n_m - 1)
    def _flush():
        out_ref[...] = acc_ref[...][None, :, None, None, :]


def batched_dw_kernel(x, dy, idx, *, block: int, tm: int = 128,
                      tk: int = 128, interpret: bool = False):
    """Compact per-expert dW: [E, K, n_shards, n_sel, block] fp32, one
    launch for all experts and shards. idx: [n_shards, n_sel]. C and K must
    divide their tiles."""
    e, m, k = x.shape
    n = dy.shape[-1]
    n_shards, n_sel = idx.shape
    tm = min(tm, m)
    tk = min(tk, k)
    assert dy.shape[:2] == (e, m)
    assert m % tm == 0 and k % tk == 0 and n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)   # blocks per shard
    n_m = m // tm

    grid = (e, n_shards, n_sel, k // tk, n_m)
    out = pl.pallas_call(
        functools.partial(_kernel, n_m=n_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tm, tk),
                             lambda ei, si, ji, ki, mi, idx_ref:
                             (ei, mi, ki)),
                pl.BlockSpec((1, tm, block),
                             lambda ei, si, ji, ki, mi, idx_ref:
                             (ei, mi, si * n_blocks + idx_ref[si, ji])),
            ],
            out_specs=pl.BlockSpec(
                (1, tk, 1, 1, block),
                lambda ei, si, ji, ki, mi, idx_ref: (ei, ki, si, ji, 0)),
            scratch_shapes=[pltpu.VMEM((tk, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, k, n_shards, n_sel, block),
                                       jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(idx, x, dy)
    return out


def _pipelined_kernel(idx_ref, x_hbm, dy_hbm, out_ref, acc_ref, *,
                      tm: int, tk: int, block: int, n_m: int, n_blocks: int):
    ei = pl.program_id(0)
    si = pl.program_id(1)
    ji = pl.program_id(2)
    ki = pl.program_id(3)
    blk_idx = si * n_blocks + idx_ref[si, ji]
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(x_ref, dy_ref):
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0].astype(jnp.float32), dy_ref[0].astype(jnp.float32),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    pltpu.emit_pipeline(
        body,
        grid=(n_m,),
        in_specs=[
            pl.BlockSpec((1, tm, tk), lambda mi: (ei, mi, ki)),
            pl.BlockSpec((1, tm, block), lambda mi: (ei, mi, blk_idx)),
        ],
        out_specs=(),
    )(x_hbm, dy_hbm)
    out_ref[...] = acc_ref[...][None, :, None, None, :]


def batched_dw_pipelined_kernel(x, dy, idx, *, block: int, tm: int = 128,
                                tk: int = 128, interpret: bool = False):
    """Double-buffered `batched_dw_kernel`: same contract, but x/dy live in
    HBM and an inner `emit_pipeline` streams the C-tiles — VMEM holds two
    in-flight tiles per operand regardless of C."""
    ensure_pipeline_emulation()
    e, m, k = x.shape
    n = dy.shape[-1]
    n_shards, n_sel = idx.shape
    tm = min(tm, m)
    tk = min(tk, k)
    assert dy.shape[:2] == (e, m)
    assert m % tm == 0 and k % tk == 0 and n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)
    n_m = m // tm

    grid = (e, n_shards, n_sel, k // tk)
    out = pl.pallas_call(
        functools.partial(_pipelined_kernel, tm=tm, tk=tk, block=block,
                          n_m=n_m, n_blocks=n_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, tk, 1, 1, block),
                lambda ei, si, ji, ki, idx_ref: (ei, ki, si, ji, 0)),
            scratch_shapes=[pltpu.VMEM((tk, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, k, n_shards, n_sel, block),
                                       jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel")),
        interpret=interpret,
    )(idx, x, dy)
    return out
