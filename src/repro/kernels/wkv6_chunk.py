"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence (one head-block).

The pure-JAX model (models/rwkv6.py) runs the recurrence as a sequential
lax.scan — exact, but latency-bound on TPU (one tiny [D,D] update per
step). This kernel processes CHUNK timesteps per grid step with the
classic two-part decomposition, keeping the state in VMEM scratch across
the sequential grid dimension:

    intra-chunk:  y_t += r_t . (decay(t,u) k_u v_u^T) for u <= t in chunk
                  (dense [C,C] masked matmuls on the MXU)
    inter-chunk:  y_t += (r_t * prod_decay(<=t)) . S;  S <- decayed S + chunk kv

Shapes (per (batch, head) grid cell):
    r, k, v, w: [T, D]  (w = per-channel decay in (0,1)); u: [D]
    out:        [T, D]
Grid: (B*H, T/C) with the time dimension "arbitrary" (sequential), state
S [D, D] in VMEM scratch.

Numerics: decays are accumulated in log space within a chunk (w in (0,1)
=> logs <= 0; C=32/64 keeps exp() in fp32 range), matching the oracle to
~1e-5 fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # [C, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)        # [1, D]
    s = s_ref[...]                            # [D, D]

    logw = jnp.log(jnp.maximum(w, 1e-12))     # [C, D], <= 0
    cum = jnp.cumsum(logw, axis=0)            # prod of decays up to & incl. t

    # inter-chunk: y_t = (r_t * exp(cum_{t-1})) @ S ; cum_{t-1} = cum_t - logw_t
    r_dec = r * jnp.exp(cum - logw)
    y = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: att[t,u] = sum_d r[t,d] k[u,d] exp(cum_{t-1,d} - cum_{u,d})
    # for u < t; diagonal uses the bonus u instead of decay.
    rd = r * jnp.exp(cum - logw)              # exp(cum_{t-1})
    ku = k * jnp.exp(-cum)                    # exp(-cum_u)
    att = jax.lax.dot_general(rd, ku, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [C, C]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(u_idx < t_idx, att, 0.0)
    diag = jnp.sum(r * (u * k), axis=1)       # bonus term at u == t
    y += jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y += diag[:, None] * v

    # state update: S <- diag(prod w) S + sum_u (k_u * exp(cum_C - cum_u)) v_u^T
    k_tail = k * jnp.exp(cum[-1:] - cum)
    s_new = jnp.exp(cum[-1])[:, None] * s + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new
    o_ref[0] = y.astype(o_ref.dtype)


def wkv6_chunk_kernel(r, k, v, w, u, *, chunk: int = 32,
                      interpret: bool = False):
    """r,k,v,w: [BH, T, D] (already merged batch*heads); u: [D].
    Returns y [BH, T, D] (fp32)."""
    bh, t, d = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    grid = (bh, t // chunk)
    u2 = u.reshape(1, d)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, d), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u2)
