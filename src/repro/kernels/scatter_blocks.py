"""Pallas TPU kernel: in-place block scatter-update for the compact path.

The compact-gradient train step updates only the selected output-channel
blocks of each weight; this kernel writes those updated blocks back into
the full weight WITHOUT sweeping (or even reading) the unselected columns.
The weight input is aliased to the output (`input_output_aliases`), so on
TPU the update is a true in-place write touching n_sel/n_blocks of the
tensor — HBM traffic proportional to the selection ratio, the memory-side
twin of `masked_dw`'s compute skip.

    w:   [R, N]              full weight, rows = flattened non-out dims
    upd: [R, n_sel, block]   updated values for the selected blocks
    idx: [n_sel]             selected block indices (N = n_blocks * block)
    out: [R, N]              w with out[:, idx[s]] block <- upd[:, s]

Grid: (n_sel, R/TR); the scalar-prefetched idx routes each grid step's
output block straight to its selected column block. If idx contains
duplicates the highest grid step wins (grid dim 0 is "arbitrary", i.e.
sequential) — selection never produces duplicates within a shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _kernel(idx_ref, w_ref, upd_ref, out_ref):
    del idx_ref, w_ref
    out_ref[...] = upd_ref[:, 0, :].astype(out_ref.dtype)


def block_scatter_update_kernel(w, upd, idx, *, tr: int = 256,
                                interpret: bool = False):
    """out = w with blocks idx overwritten by upd. Shapes as module doc."""
    r, n = w.shape
    n_sel, block = upd.shape[1], upd.shape[2]
    assert n % block == 0 and upd.shape[0] == r and idx.shape == (n_sel,)
    tr = min(tr, r)
    assert r % tr == 0

    grid = (n_sel, r // tr)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tr, block), lambda si, ri, idx_ref:
                             (ri, idx_ref[si])),
                pl.BlockSpec((tr, 1, block), lambda si, ri, idx_ref:
                             (ri, si, 0)),
            ],
            out_specs=pl.BlockSpec((tr, block), lambda si, ri, idx_ref:
                                   (ri, idx_ref[si])),
        ),
        out_shape=jax.ShapeDtypeStruct((r, n), w.dtype),
        input_output_aliases={1: 0},   # w aliases out: unselected blocks kept
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )(idx, w, upd)
