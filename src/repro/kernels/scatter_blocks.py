"""Pallas TPU kernel: in-place block scatter-update for the compact path.

The compact-gradient train step updates only the selected output-channel
blocks of each weight; this kernel writes those updated blocks back into
the full weight WITHOUT sweeping (or even reading) the unselected columns.
The weight input is aliased to the output (`input_output_aliases`), so on
TPU the update is a true in-place write touching n_sel/n_blocks of the
tensor — HBM traffic proportional to the selection ratio, the memory-side
twin of `masked_dw`'s compute skip.

ONE `pallas_call` covers the whole stacked leaf: the grid spans the K
trainable scan-steps AND the TP shards (PR 1 launched K x n_shards
separate 2D kernels from a Python loop), with the scalar-prefetched
[K, n_shards, n_sel] index table routing each grid step's output block to
`shard_base + idx[k, s, j]`.

    w:   [K, R, N]                     stacked weight, R = flattened non-out
                                       dims, N = n_shards * n_blocks * block
    upd: [K, R, n_shards, n_sel, block]  updated values for selected blocks
    idx: [K, n_shards, n_sel]          selected block indices, shard-local
    out: [K, R, N]                     w with the selected blocks overwritten

Grid: (K, n_shards, n_sel, R/TR). If idx contains duplicates within a
(k, shard) the highest grid step wins (the sel dims are "arbitrary", i.e.
sequential) — selection never produces duplicates within a shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _kernel(idx_ref, w_ref, upd_ref, out_ref):
    del idx_ref, w_ref
    out_ref[...] = upd_ref[:, :, 0, 0, :].astype(out_ref.dtype)


def block_scatter_update_kernel(w, upd, idx, *, tr: int = 256,
                                interpret: bool = False):
    """out = w with blocks idx overwritten by upd. Shapes as module doc."""
    k, r, n = w.shape
    n_shards, n_sel = idx.shape[1], idx.shape[2]
    block = upd.shape[-1]
    assert upd.shape == (k, r, n_shards, n_sel, block)
    assert idx.shape == (k, n_shards, n_sel)
    assert n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)   # blocks per shard
    tr = min(tr, r)
    assert r % tr == 0

    grid = (k, n_shards, n_sel, r // tr)
    out_spec = pl.BlockSpec(
        (1, tr, block),
        lambda kk, si, ji, ri, idx_ref:
        (kk, ri, si * n_blocks + idx_ref[kk, si, ji]))
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                out_spec,
                pl.BlockSpec((1, tr, 1, 1, block),
                             lambda kk, si, ji, ri, idx_ref:
                             (kk, ri, si, ji, 0)),
            ],
            out_specs=out_spec,
        ),
        out_shape=jax.ShapeDtypeStruct((k, r, n), w.dtype),
        input_output_aliases={1: 0},   # w aliases out: unselected blocks kept
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "parallel")),
        interpret=interpret,
    )(idx, w, upd)
