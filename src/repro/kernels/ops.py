"""Jit'd wrappers integrating the Pallas kernels with the framework.

On CPU (no TPU backend) the kernels run in interpret mode — the Pallas body
executes exactly as it would be staged for TPU, validating index maps and
block logic. On TPU the same call compiles to Mosaic.

Every logical op here is ONE `pallas_call`: the dW, writeback, and fused
optimizer kernels take the whole stacked leaf (all trainable scan-steps and
all TP shards) in a single grid launch — the lowered compact train step
contains a constant number of kernel launches per selectable weight leaf
(verified by `launch.hlo_analysis.kernel_launch_count`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import Optional

from repro.kernels.batched_dw import (batched_dw_kernel,
                                      batched_dw_pipelined_kernel)
from repro.kernels.block_act_prune import block_act_prune_kernel
from repro.kernels.masked_dw import (block_sparse_dw_kernel,
                                     block_sparse_dw_pipelined_kernel)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_tile(r: int, cap: int = 256) -> int:
    """Largest divisor of r that is <= cap (grid tile along a lead dim)."""
    for d in range(min(r, cap), 0, -1):
        if r % d == 0:
            return d
    return 1


# Budget for choosing the double-buffered dW variants: once a whole
# contraction stripe ([M, TK] activations + [M, block] dY, the worst case
# the automatic pallas pipeline may keep resident while it revisits M
# tiles) exceeds this, route through the `emit_pipeline` kernels whose VMEM
# footprint is two in-flight tiles per operand + the accumulator no matter
# how long the contraction is. ~half of a v4 core's 16 MiB VMEM.
VMEM_STRIPE_BUDGET_BYTES = 8 * 1024 * 1024


def _use_pipelined(m: int, tk: int, block: int, itemsize: int,
                   pipelined: Optional[bool]) -> bool:
    if pipelined is not None:
        return pipelined
    return m * (tk + block) * itemsize > VMEM_STRIPE_BUDGET_BYTES


def block_sparse_dw(x2, dy2, idx, spec, pipelined: Optional[bool] = None):
    """compact_dw kernel entry (see core.sparse_update.compact_dw).

    x2: [M, K], dy2: [M, N], idx: [n_shards, n_sel] ->
    [K, n_shards, n_sel, block] fp32, in ONE launch for all shards.
    pipelined: force the double-buffered variant (None = auto by VMEM
    stripe residency).
    """
    m, k = x2.shape
    tm, tk = _pick_tile(m, 128), _pick_tile(k, 128)
    kern = block_sparse_dw_pipelined_kernel if _use_pipelined(
        m, tk, spec.block, x2.dtype.itemsize, pipelined) \
        else block_sparse_dw_kernel
    return kern(x2, dy2, idx, block=spec.block, tm=tm, tk=tk,
                interpret=_interpret())


def block_sparse_dw_batched(x3, dy3, idx, spec, pipelined: Optional[bool] = None):
    """Expert-batched compact dW (see core.sparse_update.compact_dw_batched).

    x3: [E, C, K], dy3: [E, C, N], idx: [n_shards, n_sel] ->
    [E, K, n_shards, n_sel, block] fp32, in ONE launch for all experts and
    shards (the MoE expert leaf's whole backward is a single kernel)."""
    e, m, k = x3.shape
    tm, tk = _pick_tile(m, 128), _pick_tile(k, 128)
    kern = batched_dw_pipelined_kernel if _use_pipelined(
        m, tk, spec.block, x3.dtype.itemsize, pipelined) \
        else batched_dw_kernel
    return kern(x3, dy3, idx, block=spec.block, tm=tm, tk=tk,
                interpret=_interpret())


def block_scatter_update(w, vals, idx, spec):
    """Compact-path weight writeback (see core.sparse_update): overwrite the
    selected blocks of a stacked leaf with their updated values, in ONE
    aliased launch over (K, n_shards, n_sel, rows).

    w:    [K, *lead, N]                 (N = n_shards * n_blocks * block)
    vals: [K, *lead, n_shards, n_sel, block]
    idx:  [K, n_shards, n_sel]

    Stacked EXPERT leaves ride the same launch: an MoE weight
    [K, E, d, N] flattens its (E, d) lead dims into the kernel's row
    dimension R — the block rule is elementwise per row, so expert
    boundaries need no grid dimension of their own and the writeback stays
    one launch regardless of n_experts.
    """
    from repro.kernels.scatter_blocks import block_scatter_update_kernel

    k = w.shape[0]
    n = w.shape[-1]
    r = 1
    for d in w.shape[1:-1]:
        r *= d
    w3 = w.reshape(k, r, n)
    v5 = vals.reshape(k, r, spec.n_shards, spec.n_sel, spec.block)
    out = block_scatter_update_kernel(w3, v5, idx, tr=_pick_tile(r),
                                      interpret=_interpret())
    return out.reshape(w.shape)


def fused_block_optimizer(oc, p, g_sel, idx, spec, mu, nu, lr, t):
    """`optim.apply_updates_mixed`'s selectable-leaf rule as ONE in-place
    kernel: gather + SGD/momentum/AdamW block rule + writeback fused, with
    the optimizer-state blocks updated in the same pass.

    p: [K, *lead, N]; g_sel: [K, *lead, n_shards, n_sel, block];
    idx: [K, n_shards, n_sel]; mu/nu: fp32 like p or None.
    Returns (p', mu', nu') with None for absent state.

    Stacked EXPERT leaves ([K, E, d, N] with compact grads
    [K, E, d, n_shards, n_sel, block]) flatten (E, d) into the row
    dimension like `block_scatter_update` — the optimizer stays one launch
    per leaf independent of n_experts.
    """
    from repro.kernels.fused_block_opt import fused_block_opt_kernel

    kind = "adamw" if nu is not None else \
        ("momentum" if mu is not None else "sgd")
    k = p.shape[0]
    n = p.shape[-1]
    r = 1
    for d in p.shape[1:-1]:
        r *= d
    p3 = p.reshape(k, r, n)
    g5 = g_sel.reshape(k, r, spec.n_shards, spec.n_sel, spec.block)
    mu3 = mu.reshape(k, r, n) if mu is not None else None
    nu3 = nu.reshape(k, r, n) if nu is not None else None
    w_new, mu_new, nu_new = fused_block_opt_kernel(
        p3, g5, idx, lr, t, mu3, nu3, kind=kind, momentum=oc.momentum,
        beta1=oc.beta1, beta2=oc.beta2, eps=oc.eps,
        weight_decay=oc.weight_decay, tr=_pick_tile(r),
        interpret=_interpret())
    return (w_new.reshape(p.shape),
            mu_new.reshape(p.shape) if mu_new is not None else None,
            nu_new.reshape(p.shape) if nu_new is not None else None)


def block_act_prune(x, threshold: float = 0.15, block: int = 2):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    tr = _pick_tile(x2.shape[0])
    c = shape[-1]
    tc = c if c < 512 else max(d for d in (512, 256, 128, 64) if c % d == 0)
    out = block_act_prune_kernel(x2, threshold=threshold, block=block,
                                 tr=tr, tc=tc, interpret=_interpret())
    return out.reshape(shape)
