"""Jit'd wrappers integrating the Pallas kernels with the framework.

On CPU (no TPU backend) the kernels run in interpret mode — the Pallas body
executes exactly as it would be staged for TPU, validating index maps and
block logic. On TPU the same call compiles to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_act_prune import block_act_prune_kernel
from repro.kernels.masked_dw import block_sparse_dw_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def block_sparse_dw(x2, dy2, idx, spec):
    """compact_dw kernel entry (see core.sparse_update.compact_dw).

    x2: [M, K], dy2: [M, N], idx: [n_shards, n_sel] ->
    [K, n_shards, n_sel, block] fp32 (matches the jnp path layout).
    """
    n_shards, n_sel = idx.shape
    m, k = x2.shape
    n = dy2.shape[1]
    loc = n // n_shards
    outs = []
    for s in range(n_shards):  # dry-run path is jnp; kernel used per device
        dy_s = dy2[:, s * loc: (s + 1) * loc]
        out = block_sparse_dw_kernel(x2, dy_s, idx[s], block=spec.block,
                                     interpret=_interpret())
        outs.append(out)                          # [n_sel, block, K]
    stacked = jnp.stack(outs, axis=0)             # [n_shards, n_sel, block, K]
    return jnp.transpose(stacked, (3, 0, 1, 2))   # [K, n_shards, n_sel, block]


def block_scatter_update(w, vals, idx, spec):
    """Compact-path weight writeback (see core.sparse_update): overwrite the
    selected blocks of a stacked leaf with their updated values.

    w:    [K, *lead, N]                 (N = n_shards * n_blocks * block)
    vals: [K, *lead, n_shards, n_sel, block]
    idx:  [K, n_shards, n_sel]
    """
    from repro.kernels.scatter_blocks import block_scatter_update_kernel

    k = w.shape[0]
    lead = w.shape[1:-1]
    r = 1
    for d in lead:
        r *= d
    tr = r if r < 256 else max(d for d in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                               if r % d == 0)
    loc = spec.n_blocks * spec.block
    outs = []
    for kk in range(k):       # K (trainable steps) and shards are tiny loops
        wk = w[kk].reshape(r, spec.n_shards, loc)
        vk = vals[kk].reshape(r, spec.n_shards, spec.n_sel, spec.block)
        shards = [block_scatter_update_kernel(wk[:, s], vk[:, s], idx[kk, s],
                                              tr=tr, interpret=_interpret())
                  for s in range(spec.n_shards)]
        outs.append(jnp.stack(shards, axis=1).reshape(w.shape[1:]))
    return jnp.stack(outs, axis=0)


def block_act_prune(x, threshold: float = 0.15, block: int = 2):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r = x2.shape[0]
    # pick dividing tiles
    tr = r if r < 256 else max(d for d in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                               if r % d == 0)
    c = shape[-1]
    tc = c if c < 512 else max(d for d in (512, 256, 128, 64) if c % d == 0)
    out = block_act_prune_kernel(x2, threshold=threshold, block=block,
                                 tr=tr, tc=tc, interpret=_interpret())
    return out.reshape(shape)
