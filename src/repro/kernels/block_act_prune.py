"""Pallas TPU kernel: ZeBRA block activation pruning (paper §III-A.2).

Zero every `block`-wide channel run whose max |x| falls below the
threshold. Tiled elementwise kernel — one VMEM tile in, one out; the
block max is computed in-register (no extra HBM traffic).

    x: [R, C] -> same shape, sub-threshold blocks zeroed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, block: int, threshold: float):
    x = x_ref[...]
    tr, tc = x.shape
    xb = x.reshape(tr, tc // block, block)
    keep = (jnp.abs(xb).max(axis=-1, keepdims=True) >= threshold)
    o_ref[...] = (xb * keep.astype(x.dtype)).reshape(tr, tc)


def block_act_prune_kernel(x, *, threshold: float = 0.15, block: int = 2,
                           tr: int = 256, tc: int = 512,
                           interpret: bool = False):
    r, c = x.shape
    tr = min(tr, r)
    tc = min(tc, c)
    assert r % tr == 0 and c % tc == 0 and tc % block == 0
    return pl.pallas_call(
        functools.partial(_kernel, block=block, threshold=threshold),
        grid=(r // tr, c // tc),
        in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x)
