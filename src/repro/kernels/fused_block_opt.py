"""Pallas TPU kernel: fused compact-path optimizer block update.

PR 1's compact optimizer did gather -> jnp rule -> scatter as three
separate full passes over the selected blocks (three kernel families, three
rounds of HBM traffic). This kernel fuses all three into ONE in-place grid
launch per weight leaf: the BlockSpec index maps ARE the gather (the weight
and optimizer-state inputs are routed straight to their selected column
blocks), the SGD / momentum / AdamW rule runs on the tile in VMEM, and the
aliased outputs ARE the writeback — weights and optimizer state update in
the same pass, touching n_sel/n_blocks of each tensor.

    w:   [K, R, N]                       stacked weight (any float dtype)
    g:   [K, R, n_shards, n_sel, block]  compact gradient (selected blocks)
    idx: [K, n_shards, n_sel]            selected block indices, shard-local
    mu:  [K, R, N] fp32                  first moment (momentum/adamw) or None
    nu:  [K, R, N] fp32                  second moment (adamw) or None
    lr, t: traced fp32 scalars (learning rate, adamw bias-correction step),
           scalar-prefetched alongside idx.

Returns (w', mu', nu') with None for absent state; every input tensor is
aliased to its output, so unselected blocks are never read or written.

The per-tile arithmetic mirrors `repro.optim.optimizers._leaf_update`
exactly (fp32 compute, cast back to the param dtype): SGD is bitwise
identical to the jnp gather/update/scatter oracle; momentum/AdamW are
allclose (elementwise, so in practice also bitwise).

Grid: (K, n_shards, n_sel, R/TR); selection dims are "arbitrary"
(sequential) so a duplicate index cannot race — selection never produces
duplicates within a shard anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _rule(kind: str, hp: dict, lr, t, p, g, mu, nu):
    """The optimizer block rule on fp32 tiles; mirrors _leaf_update."""
    if kind == "sgd":
        new = p - lr * g
        if hp["weight_decay"]:
            new = new - lr * hp["weight_decay"] * p
        return new, None, None
    if kind == "momentum":
        mu_new = hp["momentum"] * mu + g
        new = p - lr * mu_new
        if hp["weight_decay"]:
            new = new - lr * hp["weight_decay"] * p
        return new, mu_new, None
    if kind == "adamw":
        b1, b2 = hp["beta1"], hp["beta2"]
        mu_new = b1 * mu + (1 - b1) * g
        nu_new = b2 * nu + (1 - b2) * g * g
        mu_hat = mu_new / (1 - b1 ** t)
        nu_hat = nu_new / (1 - b2 ** t)
        new = p - lr * (mu_hat / (jnp.sqrt(nu_hat) + hp["eps"])
                        + hp["weight_decay"] * p)
        return new, mu_new, nu_new
    raise ValueError(kind)


def _kernel(idx_ref, hyper_ref, *refs, kind: str, hp: dict):
    del idx_ref
    lr = hyper_ref[0]
    t = hyper_ref[1]
    n_state = {"sgd": 0, "momentum": 1, "adamw": 2}[kind]
    ins, outs = refs[: 2 + n_state], refs[2 + n_state:]
    w_ref, g_ref = ins[0], ins[1]
    p = w_ref[0].astype(jnp.float32)                 # [TR, block]
    g = g_ref[0, :, 0, 0, :].astype(jnp.float32)
    mu = ins[2][0] if n_state >= 1 else None         # fp32 already
    nu = ins[3][0] if n_state >= 2 else None
    new, mu_new, nu_new = _rule(kind, hp, lr, t, p, g, mu, nu)
    outs[0][...] = new.astype(outs[0].dtype)[None]
    if n_state >= 1:
        outs[1][...] = mu_new[None]
    if n_state >= 2:
        outs[2][...] = nu_new[None]


def fused_block_opt_kernel(w, g, idx, lr, t, mu=None, nu=None, *, kind: str,
                           momentum: float = 0.0, beta1: float = 0.9,
                           beta2: float = 0.999, eps: float = 1e-8,
                           weight_decay: float = 0.0, tr: int = 256,
                           interpret: bool = False):
    """One-launch fused block optimizer step; shapes as module doc.

    kind: "sgd" (no state), "momentum" (mu), "adamw" (mu, nu)."""
    k, r, n = w.shape
    n_shards, n_sel = idx.shape[1], idx.shape[2]
    block = g.shape[-1]
    assert g.shape == (k, r, n_shards, n_sel, block)
    assert idx.shape == (k, n_shards, n_sel)
    assert n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)
    tr = min(tr, r)
    assert r % tr == 0
    n_state = {"sgd": 0, "momentum": 1, "adamw": 2}[kind]
    assert (mu is not None) == (n_state >= 1)
    assert (nu is not None) == (n_state >= 2)

    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.asarray(t, jnp.float32)])
    full_spec = pl.BlockSpec(
        (1, tr, block),
        lambda kk, si, ji, ri, idx_ref, hyper_ref:
        (kk, ri, si * n_blocks + idx_ref[kk, si, ji]))
    g_spec = pl.BlockSpec(
        (1, tr, 1, 1, block),
        lambda kk, si, ji, ri, idx_ref, hyper_ref: (kk, ri, si, ji, 0))

    operands = [w, g] + [s for s in (mu, nu) if s is not None]
    in_specs = [full_spec, g_spec] + [full_spec] * n_state
    out_specs = [full_spec] * (1 + n_state)
    out_shape = [jax.ShapeDtypeStruct((k, r, n), w.dtype)] \
        + [jax.ShapeDtypeStruct((k, r, n), jnp.float32)] * n_state
    # operand numbering includes the two scalar-prefetch args (idx, hyper):
    # w is operand 2, mu 4, nu 5 -> aliased onto outputs 0, 1, 2.
    aliases = {2: 0}
    if n_state >= 1:
        aliases[4] = 1
    if n_state >= 2:
        aliases[5] = 2

    hp = {"momentum": momentum, "beta1": beta1, "beta2": beta2, "eps": eps,
          "weight_decay": weight_decay}
    grid = (k, n_shards, n_sel, r // tr)
    out = pl.pallas_call(
        functools.partial(_kernel, kind=kind, hp=hp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=tuple(out_specs),
        ),
        out_shape=tuple(out_shape),
        input_output_aliases=aliases,
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "parallel")),
        interpret=interpret,
    )(idx, hyper, *operands)
    w_new = out[0]
    mu_new = out[1] if n_state >= 1 else None
    nu_new = out[2] if n_state >= 2 else None
    return w_new, mu_new, nu_new
