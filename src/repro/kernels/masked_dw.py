"""Pallas TPU kernel: block-sparse weight-gradient matmul.

The paper's core compute saving — dW is computed ONLY for selected output-
channel blocks. The selected block indices are scalar-prefetched so the
BlockSpec index_map routes each grid step directly to its selected dY
column block; unselected blocks are never read, computed, or written
(compute AND HBM traffic skipped by construction — the TPU-native analogue
of the paper's skipped gradient loops).

    x:   [M, K]      activations (fan-in K)
    dy:  [M, N]      upstream gradient (N output channels)
    idx: [n_sel]     selected channel-block indices (N = n_blocks * block)
    out: [n_sel, block, K]   compact dW for the selected blocks (fp32)

Grid: (n_sel, K/TK, M/TM); M is the contraction ("arbitrary") dimension,
accumulated into the output block in VMEM across the innermost grid axis.
MXU alignment: block and TK should be multiples of 128 on real hardware
(full configs use channel_block=128); interpret-mode tests sweep smaller
shapes against the ref.py oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _kernel(idx_ref, x_ref, dy_ref, out_ref, acc_ref, *, n_m: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)      # [TM, TK]
    dy = dy_ref[...].astype(jnp.float32)    # [TM, block]
    acc_ref[...] += jax.lax.dot_general(
        dy, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [block, TK]

    @pl.when(mi == n_m - 1)
    def _flush():
        out_ref[...] = acc_ref[...][None]


def block_sparse_dw_kernel(x, dy, idx, *, block: int, tm: int = 128,
                           tk: int = 128, interpret: bool = False):
    """Compact dW: [n_sel, block, K] fp32. Shapes must divide tiles."""
    m, k = x.shape
    n = dy.shape[1]
    n_sel = idx.shape[0]
    tm = min(tm, m)
    tk = min(tk, k)
    assert m % tm == 0 and k % tk == 0 and n % block == 0
    n_m = m // tm

    grid = (n_sel, k // tk, n_m)
    out = pl.pallas_call(
        functools.partial(_kernel, n_m=n_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tk), lambda si, ki, mi, idx_ref: (mi, ki)),
                pl.BlockSpec((tm, block),
                             lambda si, ki, mi, idx_ref: (mi, idx_ref[si])),
            ],
            out_specs=pl.BlockSpec(
                (1, block, tk), lambda si, ki, mi, idx_ref: (si, 0, ki)),
            scratch_shapes=[pltpu.VMEM((block, tk), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_sel, block, k), jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(idx, x, dy)
    return out
