"""Pallas TPU kernel: block-sparse weight-gradient matmul, single launch.

The paper's core compute saving — dW is computed ONLY for selected output-
channel blocks. The selected block indices are scalar-prefetched so the
BlockSpec index_map routes each grid step directly to its selected dY
column block; unselected blocks are never read, computed, or written
(compute AND HBM traffic skipped by construction — the TPU-native analogue
of the paper's skipped gradient loops).

ONE `pallas_call` covers every TP shard: the grid spans shards as well as
selected blocks, and the scalar-prefetched [n_shards, n_sel] index table
routes the dY BlockSpec to `shard_base + idx[s, j]`. The output is emitted
directly in the framework's compact layout — no Python shard loop, no
post-hoc stack/transpose in `ops.py` (PR 1 launched one kernel per shard
and reassembled on the host side of the trace).

    x:   [M, K]            activations (fan-in K)
    dy:  [M, N]            upstream gradient (N = n_shards * n_blocks * block)
    idx: [n_shards, n_sel] selected block indices, local to each shard
    out: [K, n_shards, n_sel, block]   compact dW (fp32)

Grid: (n_shards, n_sel, K/TK, M/TM); M is the contraction ("arbitrary")
innermost dimension, accumulated into a VMEM scratch across grid steps.
MXU alignment: block and TK should be multiples of 128 on real hardware
(full configs use channel_block=128); interpret-mode tests sweep smaller
shapes against the ref.py oracle.

`block_sparse_dw_pipelined_kernel` is the double-buffered variant (ROADMAP
Kernels open item): x and dy stay in HBM (`memory_space=ANY`) and a
`pltpu.emit_pipeline` inner grid streams the M tiles through VMEM with
explicit double buffering — VMEM residency is two tiles per operand plus
the [TK, block] accumulator no matter how large M grows. `kernels.ops`
selects it when a whole contraction stripe stops fitting VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import ensure_pipeline_emulation, pallas_compiler_params


def _kernel(idx_ref, x_ref, dy_ref, out_ref, acc_ref, *, n_m: int):
    mi = pl.program_id(3)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)      # [TM, TK]
    dy = dy_ref[...].astype(jnp.float32)    # [TM, block]
    acc_ref[...] += jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [TK, block]

    @pl.when(mi == n_m - 1)
    def _flush():
        out_ref[...] = acc_ref[...][:, None, None, :]


def block_sparse_dw_kernel(x, dy, idx, *, block: int, tm: int = 128,
                           tk: int = 128, interpret: bool = False):
    """Compact dW: [K, n_shards, n_sel, block] fp32, one launch for all
    shards. idx: [n_shards, n_sel]. Shapes must divide tiles."""
    m, k = x.shape
    n = dy.shape[1]
    n_shards, n_sel = idx.shape
    tm = min(tm, m)
    tk = min(tk, k)
    assert m % tm == 0 and k % tk == 0 and n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)   # blocks per shard
    n_m = m // tm

    grid = (n_shards, n_sel, k // tk, n_m)
    out = pl.pallas_call(
        functools.partial(_kernel, n_m=n_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tk),
                             lambda si, ji, ki, mi, idx_ref: (mi, ki)),
                pl.BlockSpec((tm, block),
                             lambda si, ji, ki, mi, idx_ref:
                             (mi, si * n_blocks + idx_ref[si, ji])),
            ],
            out_specs=pl.BlockSpec(
                (tk, 1, 1, block),
                lambda si, ji, ki, mi, idx_ref: (ki, si, ji, 0)),
            scratch_shapes=[pltpu.VMEM((tk, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((k, n_shards, n_sel, block),
                                       jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(idx, x, dy)
    return out


def _pipelined_kernel(idx_ref, x_hbm, dy_hbm, out_ref, acc_ref, *,
                      tm: int, tk: int, block: int, n_m: int, n_blocks: int):
    si = pl.program_id(0)
    ji = pl.program_id(1)
    ki = pl.program_id(2)
    blk_idx = si * n_blocks + idx_ref[si, ji]
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(x_ref, dy_ref):
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), dy_ref[...].astype(jnp.float32),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    pltpu.emit_pipeline(
        body,
        grid=(n_m,),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda mi: (mi, ki)),
            pl.BlockSpec((tm, block), lambda mi: (mi, blk_idx)),
        ],
        out_specs=(),
    )(x_hbm, dy_hbm)
    out_ref[...] = acc_ref[...][:, None, None, :]


def block_sparse_dw_pipelined_kernel(x, dy, idx, *, block: int, tm: int = 128,
                                     tk: int = 128, interpret: bool = False):
    """Double-buffered `block_sparse_dw_kernel`: same contract, but x/dy
    live in HBM and an inner `emit_pipeline` streams the M tiles."""
    ensure_pipeline_emulation()
    m, k = x.shape
    n = dy.shape[1]
    n_shards, n_sel = idx.shape
    tm = min(tm, m)
    tk = min(tk, k)
    assert m % tm == 0 and k % tk == 0 and n % (n_shards * block) == 0
    n_blocks = n // (n_shards * block)
    n_m = m // tm

    grid = (n_shards, n_sel, k // tk)
    out = pl.pallas_call(
        functools.partial(_pipelined_kernel, tm=tm, tk=tk, block=block,
                          n_m=n_m, n_blocks=n_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            ],
            out_specs=pl.BlockSpec(
                (tk, 1, 1, block),
                lambda si, ji, ki, idx_ref: (ki, si, ji, 0)),
            scratch_shapes=[pltpu.VMEM((tk, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((k, n_shards, n_sel, block),
                                       jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(idx, x, dy)
    return out
