"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def block_sparse_dw_ref(x, dy, idx, block: int):
    """x: [M,K], dy: [M,N], idx: [n_sel] -> [n_sel, block, K] fp32."""
    m, k = x.shape
    n = dy.shape[1]
    dyb = dy.reshape(m, n // block, block)
    dy_sel = jnp.take(dyb, idx, axis=1)                     # [M, n_sel, block]
    return jnp.einsum("msb,mk->sbk", dy_sel.astype(jnp.float32),
                      x.astype(jnp.float32))


def block_scatter_update_ref(w, upd, idx, block: int):
    """w: [R,N], upd: [R,n_sel,block], idx: [n_sel] -> w with the selected
    blocks overwritten (unselected columns untouched)."""
    r, n = w.shape
    wb = w.reshape(r, n // block, block)
    out = wb.at[:, idx, :].set(upd.astype(w.dtype))
    return out.reshape(r, n)


def block_act_prune_ref(x, threshold: float = 0.15, block: int = 2):
    c = x.shape[-1]
    xb = x.reshape(x.shape[:-1] + (c // block, block))
    keep = (jnp.abs(xb).max(axis=-1, keepdims=True) >= threshold)
    return (xb * keep.astype(x.dtype)).reshape(x.shape)


def wkv6_ref(r, k, v, w, u):
    """Sequential RWKV-6 recurrence oracle (matches models/rwkv6._wkv_chunk
    semantics): r,k,v,w: [BH, T, D]; u: [D] -> y [BH, T, D] fp32."""
    import jax

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("bd,bde->be", rt, u[None, :, None] * kv + s)
        return wt[:, :, None] * s + kv, y

    bh, t, d = r.shape
    s0 = jnp.zeros((bh, d, d), jnp.float32)
    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0) for x in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)
