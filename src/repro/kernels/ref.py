"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def block_sparse_dw_ref(x, dy, idx, block: int):
    """x: [M,K], dy: [M,N], idx: [n_shards,n_sel] ->
    [K, n_shards, n_sel, block] fp32 (the compact-path dW layout)."""
    m, k = x.shape
    n = dy.shape[1]
    n_shards, n_sel = idx.shape
    dyb = dy.reshape(m, n_shards, n // (n_shards * block), block)
    dy_sel = jnp.take_along_axis(dyb, idx[None, :, :, None], axis=2)
    return jnp.einsum("mk,msjb->ksjb", x.astype(jnp.float32),
                      dy_sel.astype(jnp.float32))


def batched_dw_ref(x, dy, idx, block: int):
    """Per-expert compact dW oracle: x: [E,C,K], dy: [E,C,N],
    idx: [n_shards,n_sel] -> [E, K, n_shards, n_sel, block] fp32 (the
    expert-batched compact-path layout; a dense per-expert einsum gathered
    at the selection)."""
    e, m, k = x.shape
    n = dy.shape[-1]
    n_shards, n_sel = idx.shape
    dyb = dy.reshape(e, m, n_shards, n // (n_shards * block), block)
    dy_sel = jnp.take_along_axis(dyb, idx[None, None, :, :, None], axis=3)
    return jnp.einsum("eck,ecsjb->eksjb", x.astype(jnp.float32),
                      dy_sel.astype(jnp.float32))


def _block_idx5(idx, r: int, block: int):
    """[K, S, n_sel] -> broadcast gather/scatter index [K, R, S, n_sel, blk]."""
    k, s, n_sel = idx.shape
    return jnp.broadcast_to(idx[:, None, :, :, None], (k, r, s, n_sel, block))


def block_scatter_update_ref(w, upd, idx, block: int):
    """w: [K,R,N], upd: [K,R,n_shards,n_sel,block], idx: [K,n_shards,n_sel]
    -> w with the selected blocks overwritten (unselected untouched)."""
    k, r, n = w.shape
    n_shards = idx.shape[1]
    wb = w.reshape(k, r, n_shards, n // (n_shards * block), block)
    out = jnp.put_along_axis(wb, _block_idx5(idx, r, block),
                             upd.astype(w.dtype), axis=3, inplace=False)
    return out.reshape(k, r, n)


def fused_block_opt_ref(w, g, idx, lr, t, mu=None, nu=None, *, kind: str,
                        momentum: float = 0.0, beta1: float = 0.9,
                        beta2: float = 0.999, eps: float = 1e-8,
                        weight_decay: float = 0.0):
    """Gather -> optimizer block rule -> scatter, as three jnp passes (the
    un-fused oracle for fused_block_opt; arithmetic mirrors
    optim.optimizers._leaf_update). Shapes as fused_block_opt's module doc;
    returns (w', mu', nu') with None for absent state."""
    k, r, n = w.shape
    block = g.shape[-1]
    n_shards = idx.shape[1]
    bidx = _block_idx5(idx, r, block)

    def gather(a):
        ab = a.reshape(k, r, n_shards, n // (n_shards * block), block)
        return jnp.take_along_axis(ab, bidx, axis=3)

    def scatter(a, vals):
        ab = a.reshape(k, r, n_shards, n // (n_shards * block), block)
        out = jnp.put_along_axis(ab, bidx, vals.astype(a.dtype), axis=3,
                                 inplace=False)
        return out.reshape(k, r, n)

    p32 = gather(w).astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    if kind == "sgd":
        new = p32 - lr * g32
        if weight_decay:
            new = new - lr * weight_decay * p32
        return scatter(w, new), None, None
    if kind == "momentum":
        mu_new = momentum * gather(mu) + g32
        new = p32 - lr * mu_new
        if weight_decay:
            new = new - lr * weight_decay * p32
        return scatter(w, new), scatter(mu, mu_new), None
    if kind == "adamw":
        mu_new = beta1 * gather(mu) + (1 - beta1) * g32
        nu_new = beta2 * gather(nu) + (1 - beta2) * g32 * g32
        mu_hat = mu_new / (1 - beta1 ** t)
        nu_hat = nu_new / (1 - beta2 ** t)
        new = p32 - lr * (mu_hat / (jnp.sqrt(nu_hat) + eps)
                          + weight_decay * p32)
        return scatter(w, new), scatter(mu, mu_new), scatter(nu, nu_new)
    raise ValueError(kind)


def block_act_prune_ref(x, threshold: float = 0.15, block: int = 2):
    c = x.shape[-1]
    xb = x.reshape(x.shape[:-1] + (c // block, block))
    keep = (jnp.abs(xb).max(axis=-1, keepdims=True) >= threshold)
    return (xb * keep.astype(x.dtype)).reshape(x.shape)


def wkv6_ref(r, k, v, w, u):
    """Sequential RWKV-6 recurrence oracle (matches models/rwkv6._wkv_chunk
    semantics): r,k,v,w: [BH, T, D]; u: [D] -> y [BH, T, D] fp32."""
    import jax

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("bd,bde->be", rt, u[None, :, None] * kv + s)
        return wt[:, :, None] * s + kv, y

    bh, t, d = r.shape
    s0 = jnp.zeros((bh, d, d), jnp.float32)
    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0) for x in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)
