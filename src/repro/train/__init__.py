from repro.train.steps import (TrainState, make_train_state, make_train_step,
                               split_params)

__all__ = ["TrainState", "make_train_state", "make_train_step", "split_params"]
