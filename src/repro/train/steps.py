"""Train-state and train-step builders (dense baseline + DGSU sparse).

The state is a plain dict pytree (msgpack-serializable for checkpoints):

    {"step", "params_trainable", "params_frozen", "opt", "sel_idx", "rng"}

One compiled train_step serves all three schedule phases: the dynamic phase
only changes the *values* of sel_idx (int32 data, re-randomized in-graph).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import (build_plan, magnitude_selection, random_selection)
from repro.core.schedule import maybe_reselect
from repro.core.selection import SelectionPlan
from repro.core.sparse_update import split_stack
from repro.models import transformer as T
from repro.optim import apply_updates, apply_updates_mixed, init_opt_state

TrainState = dict  # alias: plain pytree


def split_params(params, plan: SelectionPlan):
    """Split full params into (frozen, trainable) trees per the plan."""
    frozen: dict = {"segments": {}}
    trainable: dict = {"segments": {}}
    for key in params:
        if key == "segments":
            continue
        if plan.update_embeddings and key in ("embed", "lm_head"):
            trainable[key] = params[key]
        else:
            frozen[key] = params[key]
    for seg_name, stack in params["segments"].items():
        k = plan.seg_trainable.get(seg_name, 0)
        f, t = split_stack(stack, k)
        if f is not None:
            frozen["segments"][seg_name] = f
        if t is not None:
            trainable["segments"][seg_name] = t
    return frozen, trainable


def merge_params(frozen, trainable):
    """Inverse of split_params (for checkpoint export / eval)."""
    from repro.core.sparse_update import merge_stack
    out = {}
    for tree in (frozen, trainable):
        for key, val in (tree or {}).items():
            if key == "segments":
                continue
            out[key] = val
    segs = {}
    f_segs = (frozen or {}).get("segments", {})
    t_segs = (trainable or {}).get("segments", {})
    for name in set(f_segs) | set(t_segs):
        segs[name] = merge_stack(f_segs.get(name), t_segs.get(name))
    out["segments"] = segs
    return out


def make_train_state(tc: TrainConfig, key, params=None,
                     selection_init: str = "magnitude") -> tuple[TrainState, SelectionPlan]:
    cfg = tc.model
    kp, ks = jax.random.split(key)
    if params is None:
        params = T.init_params(cfg, kp)
    if tc.sparse.enabled:
        tokens_per_device = tc.shape.global_batch * tc.shape.seq_len  # 1 host
        plan = build_plan(cfg, tc.sparse, tokens_per_device)
        if selection_init == "magnitude":
            sel_idx = magnitude_selection(plan, params)
        else:  # "random": trace-friendly (dry-run abstract state)
            sel_idx = random_selection(plan, kp)
    else:
        plan = build_plan(cfg, tc.sparse.__class__(
            enabled=False, update_ratio=1.0,
            num_update_layers=10**9, channel_block=tc.sparse.channel_block))
        sel_idx = None
    frozen, trainable = split_params(params, plan)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "params_trainable": trainable,
        "params_frozen": frozen,
        "opt": init_opt_state(tc.optimizer, trainable),
        "sel_idx": sel_idx,
        "rng": ks,
    }
    return state, plan


def make_train_step(tc: TrainConfig, plan: SelectionPlan,
                    use_selection: bool = True, donate: bool = True,
                    compact_grads: Optional[bool] = None):
    """Returns a jit-able train_step(state, batch) -> (state, metrics).

    donate: whether the caller should donate the state argument when jitting
    (the returned function carries the matching `donate_argnums` attribute —
    jit as `jax.jit(fn, donate_argnums=fn.donate_argnums)` so the old
    state's buffers are reused in place; pass donate=False when the same
    input state must stay live across calls, e.g. A/B comparisons).

    compact_grads (default: tc.compact_grads) routes every segment weight
    with a SelSpec through the compact-gradient path: the backward emits the
    [K, n_shards, n_sel, block] dW directly (no full-shape zero-buffer
    scatter), the optimizer updates gathered weight/state blocks, and the
    result is scatter-written into the full weights once. Non-selectable
    leaves (norms, routers, embeddings) keep the dense path."""
    cfg = tc.model
    remat = tc.remat != "none"
    if compact_grads is None:
        compact_grads = tc.compact_grads

    def train_step(state, batch):
        step = state["step"]
        key = jax.random.fold_in(state["rng"], step)
        sel_idx = state["sel_idx"]
        if use_selection and tc.sparse.enabled and sel_idx is not None:
            sel_idx = maybe_reselect(plan, tc.sparse, sel_idx, step, key)
            sel = (sel_idx, plan.spec)
        else:
            sel = None

        trainable = state["params_trainable"]
        if compact_grads and sel is not None:
            from repro.core.sparse_update import (gather_selected_tree,
                                                  map_selectable)
            wsel = gather_selected_tree(trainable.get("segments", {}),
                                        sel_idx, plan.spec)
            spec_top = {"segments": plan.spec}

            def loss_of(diff):
                t_tree, ws = diff
                # selectable leaves only feed the forward matmul; their
                # gradient arrives compactly via `ws`
                stopped = map_selectable(t_tree, spec_top,
                                         jax.lax.stop_gradient)
                return T.loss_fn(cfg, (state["params_frozen"], stopped),
                                 batch, sel=(sel_idx, plan.spec, ws),
                                 remat=remat)

            (loss, metrics), (g_dense, g_sel) = jax.value_and_grad(
                loss_of, has_aux=True)((trainable, wsel))
            new_params, new_opt = apply_updates_mixed(
                tc.optimizer, trainable, g_dense, g_sel, state["opt"], step,
                sel_idx, plan.spec)
        else:
            def loss_of(t_tree):
                return T.loss_fn(cfg, (state["params_frozen"], t_tree),
                                 batch, sel=sel, remat=remat)

            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(trainable)
            from repro.core.sparse_update import (compact_allreduce_enabled,
                                                  compress_grads)
            if (compact_allreduce_enabled() and sel is not None
                    and "segments" in grads):
                from repro.models.specs import param_logical_specs
                logical = param_logical_specs(cfg).get("segments", {})
                grads = dict(grads)
                grads["segments"] = compress_grads(grads["segments"], sel_idx,
                                                   plan.spec, logical)
            new_params, new_opt = apply_updates(tc.optimizer, trainable,
                                                grads, state["opt"], step)
        new_state = {
            "step": step + 1,
            "params_trainable": new_params,
            "params_frozen": state["params_frozen"],
            "opt": new_opt,
            "sel_idx": sel_idx,
            "rng": state["rng"],
        }
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    train_step.donate_argnums = (0,) if donate else ()
    return train_step


def make_online_wave(cfg, sparse, optimizer, plan: SelectionPlan, *,
                     wave_tokens: int, kernels: bool = False,
                     remat: str = "selected"):
    """Builds the serve engine's online personalization train wave.

    Returns a jit-able `wave(trainable_base, frozen, delta_vals, sel_idx,
    batch, rng) -> (new_delta_vals, metrics)` that advances one user's
    compact delta (`repro.core.delta`) by one step of the existing 2-launch
    compact train step, WITHOUT touching the shared base params:

      1. materialize `base + delta` for the trainable suffix (gather-add +
         scatter — a transient copy of only the K trainable layers),
      2. run the compact-gradient train step on it (step index pinned to 0
         so the three-phase schedule never reselects; requires
         `sparse.phase_fixed_early >= 1`),
      3. re-extract `gather(new) - gather(base)` as the updated delta.

    The reported loss is computed BEFORE the update, so a falling sequence
    of wave losses on one user's traffic demonstrates personalization.
    Restricted to stateless optimizers (sgd, momentum 0) — per-user state
    is the delta and nothing else, matching the compact step's bitwise
    guarantee. The kernel-routing flag is baked in at trace time via
    `use_kernels`, keeping the pinned 2-launch-per-leaf property: the
    materialize/extract gathers stay on the jnp path and add no launches.
    """
    from repro.configs.base import ShapeConfig
    from repro.core.delta import apply_delta_tree, extract_delta_tree
    from repro.core.sparse_update import use_kernels

    assert optimizer.kind == "sgd" and optimizer.momentum == 0.0, (
        "online waves keep no per-user optimizer state: use sgd, momentum 0")
    assert sparse.phase_fixed_early >= 1, (
        "wave pins step=0; phase_fixed_early=0 would reselect in-wave")
    tc = TrainConfig(model=cfg,
                     shape=ShapeConfig("wave", wave_tokens, 1, "train"),
                     sparse=sparse, optimizer=optimizer, remat=remat,
                     compact_grads=True)
    step = make_train_step(tc, plan, use_selection=True, donate=False,
                           compact_grads=True)

    def wave(trainable_base, frozen, delta_vals, sel_idx, batch, rng):
        base_segs = trainable_base.get("segments", {})
        pers = dict(trainable_base)
        pers["segments"] = apply_delta_tree(base_segs, delta_vals, sel_idx,
                                            plan.spec)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "params_trainable": pers,
            "params_frozen": frozen,
            "opt": init_opt_state(tc.optimizer, pers),
            "sel_idx": sel_idx,
            "rng": rng,
        }
        if kernels:
            with use_kernels(True):
                new_state, metrics = step(state, batch)
        else:
            new_state, metrics = step(state, batch)
        new_vals = extract_delta_tree(
            base_segs, new_state["params_trainable"]["segments"], sel_idx,
            plan.spec)
        return new_vals, metrics

    return wave
