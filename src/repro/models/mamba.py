"""Mamba-1 selective-scan block (jamba's SSM mixer), pure JAX.

Memory strategy: everything of size [B, S, d_inner] is materialized once;
the [B, S, d_inner, d_state] discretized tensors only ever exist per-chunk
inside a rematerialized (jax.checkpoint) chunk scan whose carry is the
[B, d_inner, d_state] state — so training memory is O(S·d_inner +
chunk·d_inner·d_state), the SSM analogue of flash attention.

TP: d_inner is sharded over the model axis; the recurrence is elementwise
in d_inner so it needs no collectives. At serve time (inside the paged
shard_map) in_proj and x_proj are ROW-parallel — `in_proj` packs the x/z
halves on one output axis, so column-sharding it would split each
contiguous weight slice across the halves; sharding the INPUT dim keeps
both full-width halves addressable and one psum reassembles them, after
which each shard slices its own d_inner channel block. conv, the ssm scan,
dt_proj and the gate then run entirely on the local channel shard, and
out_proj is row-parallel back into d_model. The recurrent cache enters the
shard_map replicated; shards slice their channels in and all_gather them
back out.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparse_update import smm
from repro.models.common import dense_init
from repro import sharding as SH
from repro.sharding import constrain

CHUNK = 64


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = d_inner(cfg)
    ns = cfg.ssm.d_state
    dr = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, ns + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm.d_conv, di), dtype=dtype, scale=1.0),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dr + 2 * ns), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dr, di), dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,), jnp.float32) * 0.1,
                     1e-3, None))).astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype=dtype),
    }


def _causal_depthwise_conv(x, w, b):
    """x: [B, S, C]; w: [K, C] causal depthwise conv."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _discretize(a, dt, xc, b_ssm):
    """dt, xc: [B,Q,D] fp32; b_ssm: [B,Q,N] -> dA, dBx [B,Q,D,N] fp32."""
    dA = jnp.exp(dt[..., None] * a)
    dBx = (dt * xc)[..., None] * b_ssm[..., None, :]
    return dA, dBx


def _ssm_chunk(a, carry, chunk):
    """carry: h [B, D, N]; chunk: (dt, xc, b, c) sized [B,Q,D]/[B,Q,N].
    The [B, Q, D, N] discretized tensors exist only inside this
    (rematerialized) chunk."""
    h0 = carry
    dt, xc, b_ssm, c = chunk
    dA, dBx = _discretize(a, dt, xc, b_ssm)
    # associative affine scan: (a, b) o (a', b') = (a*a', a'*b + b')
    def op(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])
    a_cum, b_cum = jax.lax.associative_scan(op, (dA, dBx), axis=1)
    h = a_cum * h0[:, None] + b_cum                   # [B, Q, D, N]
    y = jnp.einsum("bqdn,bqn->bqd", h, c)
    return h[:, -1], y


def selective_scan(a, dt, xc, b_ssm, c, h0):
    """dt, xc: [B, S, D] fp32; b_ssm, c: [B, S, N] -> (y [B,S,D], h_last)."""
    b, s, d = dt.shape
    q = min(CHUNK, s)
    assert s % q == 0
    nc = s // q
    resh = lambda t: t.reshape((b, nc, q) + t.shape[2:]).swapaxes(0, 1)
    body = jax.checkpoint(partial(_ssm_chunk, a))
    h_last, ys = jax.lax.scan(body, h0, (resh(dt), resh(xc),
                                         resh(b_ssm), resh(c)))
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    return y, h_last


def apply_mamba(p, cfg, x, sel=None, cache=None, length=None):
    """x: [B, S, d]. cache (decode): {"h": [B,D,N], "conv": [B, K-1, D]}.
    Returns (out, new_cache|None).

    length [B] (cached chunk path only, None = all s): valid tokens per
    row. Padded rows must not advance the recurrent state — their dt is
    forced to 0 (exp(0·A)=1, zero input: an identity transition), and the
    conv history tail is gathered at the per-row valid end rather than the
    chunk end — so `h`/`conv` come back exactly as after the valid prefix.
    """
    b, s, d = x.shape
    di = d_inner(cfg)
    ns = cfg.ssm.d_state
    dr = dt_rank(cfg)

    # serve-mesh detection: out_proj arrives with its d_inner rows sharded
    ax = SH.current_mapped_axis()
    di_loc = p["out_proj"].shape[-2]
    local = ax is not None and di_loc != di
    if local:
        shard = jax.lax.axis_index(ax)
        d_loc = p["in_proj"].shape[-2]
        # in_proj row-parallel: contract the local d_model rows, psum the
        # full-width [B, S, 2*di] so the x/z halves stay addressable
        x_rows = jax.lax.dynamic_slice_in_dim(x, shard * d_loc, d_loc, axis=-1)
        xz = jax.lax.psum(smm(x_rows, p["in_proj"], sel, "in_proj"), ax)
    else:
        xz = smm(x, p["in_proj"], sel, "in_proj")
    x_in, z = jnp.split(xz, 2, axis=-1)
    if local:
        # everything below runs on this shard's d_inner channel block
        x_in = jax.lax.dynamic_slice_in_dim(x_in, shard * di_loc, di_loc, -1)
        z = jax.lax.dynamic_slice_in_dim(z, shard * di_loc, di_loc, -1)
        if cache is not None:
            cache = {
                "h": jax.lax.dynamic_slice_in_dim(
                    cache["h"], shard * di_loc, di_loc, axis=1),
                "conv": jax.lax.dynamic_slice_in_dim(
                    cache["conv"], shard * di_loc, di_loc, axis=-1),
            }
    x_in = constrain(x_in, "batch", "seq", "d_inner")

    if cache is None:
        x_c = jax.nn.silu(_causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"]))
        new_conv = None
    elif s == 1:
        hist = jnp.concatenate([cache["conv"], x_in], axis=1)  # [B, K-1+1, D]
        w = p["conv_w"]
        acc = jnp.einsum("bkd,kd->bd", hist.astype(jnp.float32),
                         w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
        x_c = jax.nn.silu(acc)[:, None, :].astype(x.dtype)
        new_conv = hist[:, 1:]
    else:
        # chunked prefill: conv over [history ++ chunk], keeping the chunk's
        # outputs (each has its full K-1 causal history) and the new tail —
        # the last K-1 VALID inputs, i.e. hist rows [length, length + K-1)
        # (hist row i holds input i - (K-1) of the chunk)
        hist = jnp.concatenate([cache["conv"], x_in], axis=1)  # [B, K-1+S, D]
        full = _causal_depthwise_conv(hist, p["conv_w"], p["conv_b"])
        x_c = jax.nn.silu(full[:, cache["conv"].shape[1]:])
        n_hist = cache["conv"].shape[1]
        if length is None:
            new_conv = hist[:, -n_hist:]
        else:
            tail = length[:, None] + jnp.arange(n_hist)[None, :]   # [B, K-1]
            new_conv = jnp.take_along_axis(hist, tail[:, :, None], axis=1)

    # x_proj row-parallel under the mesh: local channels in, small full
    # [dt_rank + 2*d_state] out, one psum
    dbl = smm(x_c, p["x_proj"], sel, "x_proj")
    if local:
        dbl = jax.lax.psum(dbl, ax)
    dt, b_ssm, c_ssm = jnp.split(dbl, [dr, dr + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                      # [B,S,D] fp32
    if length is not None and s > 1:
        # padded rows: dt=0 makes the discretized step an identity (dA=1,
        # dBx=0), freezing h at its value after the valid prefix
        dt = jnp.where(jnp.arange(s)[None, :, None] < length[:, None, None],
                       dt, 0.0)
    a = -jnp.exp(p["A_log"])                                   # [D,N]
    xc32 = x_c.astype(jnp.float32)
    b32 = b_ssm.astype(jnp.float32)
    c32 = c_ssm.astype(jnp.float32)

    h0 = cache["h"] if cache is not None \
        else jnp.zeros((b, x_in.shape[-1], ns), jnp.float32)
    if cache is not None and s == 1:
        dA, dBx = _discretize(a, dt[:, 0], xc32[:, 0], b32[:, 0])
        h_last = dA * h0 + dBx
        y = jnp.einsum("bdn,bn->bd", h_last, c32[:, 0])[:, None]
    else:
        y, h_last = selective_scan(a, dt, xc32, b32, c32, h0)

    y = y + p["D"] * x_c.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    # out_proj row-parallel: local channels contract, psum back into d_model
    out = smm(y, p["out_proj"], sel, "out_proj")
    if local:
        out = jax.lax.psum(out, ax)
        if cache is not None:
            # state must leave the shard_map replicated: gather the channel
            # blocks back (exact — per-channel values are concatenated)
            h_last = SH.all_gather_mapped(h_last, axis=1)
            new_conv = SH.all_gather_mapped(new_conv, axis=-1)
    new_cache = None if cache is None else {"h": h_last, "conv": new_conv}
    return out, new_cache


def init_mamba_cache(cfg, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, d_inner(cfg), cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, d_inner(cfg)), dtype),
    }


def mamba_snapshot_leaves(cfg, dtype):
    """Per-row (shape, dtype) spec of the mamba recurrent state — the ssm
    carry `h` plus the depthwise-conv tail — as a prefix-cache snapshot."""
    return {"h": ((d_inner(cfg), cfg.ssm.d_state), jnp.float32),
            "conv": ((cfg.ssm.d_conv - 1, d_inner(cfg)), jnp.dtype(dtype))}
