"""Shared model utilities: init, dtype policy, pytree param helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[in_axis] if in_axis is not None else int(np.prod(shape[:-1]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def last_valid(x, length):
    """x[:, length-1] per row ([B, S, ...] -> [B, ...]); x[:, -1] when
    `length` is None (serving chunks are padded to a fixed shape — the last
    VALID position is per-row data, not the last array position)."""
    if length is None:
        return x[:, -1]
    idx = length.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx - 1, axis=1)[:, 0]


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def stack_layers(layer_params: list):
    """Stack a list of identically-structured pytrees along a new leading axis
    (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def assert_finite(tree, where: str = ""):
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if not bool(jnp.isfinite(leaf).all()):
            raise FloatingPointError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")
