"""Shared model utilities: init, dtype policy, pytree param helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[in_axis] if in_axis is not None else int(np.prod(shape[:-1]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def last_valid(x, length):
    """x[:, length-1] per row ([B, S, ...] -> [B, ...]); x[:, -1] when
    `length` is None (serving chunks are padded to a fixed shape — the last
    VALID position is per-row data, not the last array position)."""
    if length is None:
        return x[:, -1]
    idx = length.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx - 1, axis=1)[:, 0]


def delta_matmul_add(y, x, delta, name: str):
    """Per-user compact-delta correction applied at matmul time.

    `y = smm(x, w)` already holds the base-model projection; this adds the
    user contribution `x @ delta` into ONLY the selected output-channel
    blocks — the gather-add dual of `scatter_param_blocks`, so no dense
    per-user weight copy ever exists. Per-row deltas make personalization
    pure batch data under the jitted decode step (no per-user retrace);
    zero-valued rows are an exact no-op, so frozen-prefix layers and
    non-personalized batch rows share the same trace.

      y      [B, S, N]
      delta["val"][name]  [B, d_in, n_shards, n_sel, block]  f32
      delta["idx"][name]  [B, n_shards, n_sel]               int32
    """
    if delta is None or name not in delta["val"]:
        return y
    val, idx = delta["val"][name], delta["idx"][name]
    b, s, n = y.shape
    n_shards, n_sel, block = val.shape[-3:]
    n_blocks = n // (n_shards * block)
    extra = jnp.einsum("bsk,bkhjc->bshjc", x, val,
                       preferred_element_type=jnp.float32)
    yb = y.reshape(b, s, n_shards, n_blocks, block).astype(jnp.float32)
    rows = jnp.arange(b)[:, None, None]
    shards = jnp.arange(n_shards)[None, :, None]
    # advanced indices at axes 0/2/3 with a slice between -> result batch
    # dims [B, h, j] lead, so move extra's seq axis after the index axes
    yb = yb.at[rows, :, shards, idx].add(extra.transpose(0, 2, 3, 1, 4))
    return yb.reshape(b, s, n).astype(y.dtype)


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def stack_layers(layer_params: list):
    """Stack a list of identically-structured pytrees along a new leading axis
    (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def assert_finite(tree, where: str = ""):
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if not bool(jnp.isfinite(leaf).all()):
            raise FloatingPointError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")
