"""Shared model utilities: init, dtype policy, pytree param helpers, and the
parallel-linear primitives (`col_matmul` / `row_matmul` / vocab-parallel
gather + logits) every layer in the zoo is built from.

Outside a shard_map all primitives reduce exactly to their single-device
spelling (`smm` + `delta_matmul_add`), so training and the single-device
serve path stay bit-identical. Inside a shard_map (serve mesh) they detect
whether the weight actually arrived sharded — the spec builder only shards
divisible dims — and fall back to replicated math otherwise, so indivisible
leaves (e.g. rwkv time-mix mats with H % shards != 0) degrade gracefully.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_update import smm
from repro import sharding as SH


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[in_axis] if in_axis is not None else int(np.prod(shape[:-1]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def last_valid(x, length):
    """x[:, length-1] per row ([B, S, ...] -> [B, ...]); x[:, -1] when
    `length` is None (serving chunks are padded to a fixed shape — the last
    VALID position is per-row data, not the last array position)."""
    if length is None:
        return x[:, -1]
    idx = length.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx - 1, axis=1)[:, 0]


def delta_matmul_add(y, x, delta, name: str):
    """Per-user compact-delta correction applied at matmul time.

    `y = smm(x, w)` already holds the base-model projection; this adds the
    user contribution `x @ delta` into ONLY the selected output-channel
    blocks — the gather-add dual of `scatter_param_blocks`, so no dense
    per-user weight copy ever exists. Per-row deltas make personalization
    pure batch data under the jitted decode step (no per-user retrace);
    zero-valued rows are an exact no-op, so frozen-prefix layers and
    non-personalized batch rows share the same trace.

      y      [B, S, N]
      delta["val"][name]  [B, d_in, n_shards, n_sel, block]  f32
      delta["idx"][name]  [B, n_shards, n_sel]               int32
    """
    if delta is None or name not in delta["val"]:
        return y
    val, idx = delta["val"][name], delta["idx"][name]
    b, s, n = y.shape
    n_shards, n_sel, block = val.shape[-3:]
    n_blocks = n // (n_shards * block)
    extra = jnp.einsum("bsk,bkhjc->bshjc", x, val,
                       preferred_element_type=jnp.float32)
    yb = y.reshape(b, s, n_shards, n_blocks, block).astype(jnp.float32)
    rows = jnp.arange(b)[:, None, None]
    shards = jnp.arange(n_shards)[None, :, None]
    # advanced indices at axes 0/2/3 with a slice between -> result batch
    # dims [B, h, j] lead, so move extra's seq axis after the index axes
    yb = yb.at[rows, :, shards, idx].add(extra.transpose(0, 2, 3, 1, 4))
    return yb.reshape(b, s, n).astype(y.dtype)


def _delta_one(name: str, val, idx):
    return {"val": {name: val}, "idx": {name: idx}}


def _delta_local_col(y, x, delta, name: str, full_out: int, ax):
    """Apply a compact delta to a COLUMN-sharded output: each shard adds only
    the selected blocks it owns, so the correction needs no collective and is
    bit-identical to the single-device `delta_matmul_add` (non-owned blocks
    contribute exact zeros)."""
    val, idx = delta["val"][name], delta["idx"][name]
    n_loc = y.shape[-1]
    tp = full_out // n_loc
    shard = jax.lax.axis_index(ax)
    n_shards, n_sel, block = val.shape[-3:]
    if n_shards % tp == 0:
        # Selection layout is TP-aligned (equal blocks per shard, indices
        # local to each selection shard): slice this shard's shards.
        k = n_shards // tp
        val = jax.lax.dynamic_slice_in_dim(val, shard * k, k, axis=-3)
        idx = jax.lax.dynamic_slice_in_dim(idx, shard * k, k, axis=-2)
        return delta_matmul_add(y, x, _delta_one(name, val, idx), name)
    if n_shards == 1 and n_loc % block == 0:
        # Global block indices: mask to the blocks this shard owns; zeroed
        # val rows are an exact no-op in the scatter-add.
        bps = n_loc // block
        own = (idx // bps) == shard                      # [B, 1, n_sel]
        idx_loc = jnp.where(own, idx - shard * bps, 0)
        val_loc = jnp.where(own[:, None, :, :, None], val, 0.0)
        return delta_matmul_add(y, x, _delta_one(name, val_loc, idx_loc),
                                name)
    # Misaligned block size: scatter into a full-width zero buffer and slice
    # the local columns (still exact: y + (0 + extra) == y + extra in f32).
    yf = jnp.zeros(y.shape[:-1] + (full_out,), jnp.float32)
    yf = delta_matmul_add(yf, x, delta, name)
    corr = jax.lax.dynamic_slice_in_dim(yf, shard * n_loc, n_loc, axis=-1)
    return (y.astype(jnp.float32) + corr).astype(y.dtype)


def col_matmul(x, w, sel, name: str, delta=None, *,
               full_out: Optional[int] = None):
    """Column-parallel linear: weight sharded on the OUTPUT axis, so the
    local matmul needs no collective and the result stays sharded on its
    last axis. Exactly `delta_matmul_add(smm(x, w, sel, name), ...)` outside
    a shard_map or when the weight arrived replicated (`w.shape[-1] ==
    full_out`); pass `full_out` wherever a delta may ride along on a mesh."""
    y = smm(x, w, sel, name)
    if delta is None or name not in delta["val"]:
        return y
    ax = SH.current_mapped_axis()
    if ax is None or full_out is None or w.shape[-1] == full_out:
        return delta_matmul_add(y, x, delta, name)
    return _delta_local_col(y, x, delta, name, full_out, ax)


def row_matmul(x, w, sel, name: str, delta=None, *,
               full_in: Optional[int] = None):
    """Row-parallel linear: weight sharded on the INPUT axis (x holds the
    matching local slice), one psum over the mapped axis reassembles the
    full output. Reduces to a plain `smm` (+ delta) outside a shard_map or
    when the weight arrived replicated (`w.shape[-2] == full_in`). A delta
    contracts over the sharded input axis, so each shard applies its d_in
    slice of the compact correction before the psum — the reduction
    reassembles the full `x @ delta`."""
    y = smm(x, w, sel, name)
    ax = SH.current_mapped_axis()
    sharded = (ax is not None and full_in is not None
               and w.shape[-2] != full_in)
    if delta is not None and name in delta["val"]:
        d = delta
        if sharded:
            d_loc = w.shape[-2]
            shard = jax.lax.axis_index(ax)
            val = jax.lax.dynamic_slice_in_dim(
                delta["val"][name], shard * d_loc, d_loc, axis=1)
            d = _delta_one(name, val, delta["idx"][name])
        y = delta_matmul_add(y, x, d, name)
    return jax.lax.psum(y, ax) if sharded else y


def vocab_parallel_gather(emb, ids, vocab_size: int):
    """Embedding lookup that works on a vocab-sharded table: each shard
    gathers the rows it owns (out-of-shard ids clipped, their rows masked to
    exact zero) and a psum reassembles the full embedding — each token's row
    lives on exactly one shard, so the sum is bit-exact. Plain `jnp.take`
    outside a shard_map or when the table arrived replicated."""
    ax = SH.current_mapped_axis()
    v_loc = emb.shape[0]
    if ax is None or v_loc == vocab_size:
        return jnp.take(emb, ids, axis=0)
    shard = jax.lax.axis_index(ax)
    local = ids - shard * v_loc
    in_range = (local >= 0) & (local < v_loc)
    rows = jnp.take(emb, jnp.clip(local, 0, v_loc - 1), axis=0)
    rows = jnp.where(in_range[..., None], rows, 0).astype(emb.dtype)
    return jax.lax.psum(rows, ax)


def vocab_parallel_logits(x, w_head, vocab_size: int):
    """LM head on a vocab-sharded weight: the [B, d] x [d, V/n] matmul runs
    on the local vocab shard (the FLOPs win), then a tiled all_gather
    reassembles the full [B, V] logits — column blocks are concatenated in
    shard order, so values are bit-identical to the unsharded einsum up to
    layout. Plain einsum outside a shard_map or when replicated."""
    logits = jnp.einsum("bd,dv->bv", x, w_head,
                        preferred_element_type=jnp.float32)
    ax = SH.current_mapped_axis()
    if ax is None or w_head.shape[-1] == vocab_size:
        return logits
    return jax.lax.all_gather(logits, ax, axis=-1, tiled=True)


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def stack_layers(layer_params: list):
    """Stack a list of identically-structured pytrees along a new leading axis
    (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def assert_finite(tree, where: str = ""):
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if not bool(jnp.isfinite(leaf).all()):
            raise FloatingPointError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")
