"""RWKV-6 "Finch" block: token-shift time-mix with data-dependent decay.

WKV recurrence (per head, head_dim D):
    y_t = r_t · (diag(u) k_t v_tᵀ + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
with per-channel decay w_t = exp(-exp(wlog_t)) produced by a low-rank
data-dependent path (the Finch contribution).

Implementation: lax.scan over time in chunks with jax.checkpoint (memory
O(chunk); the state is [B, H, D, D]). Sequential-scan latency on real TPU is
the motivation for the chunked Pallas kernel listed in DESIGN §6; for
correctness, dry-run lowering, and CPU validation this form is exact.

Simplification vs the full Finch block (recorded in DESIGN §8): the five
token-shift interpolations use per-channel learned mu (RWKV-5 style lerp)
rather than the stacked data-dependent lora for all of r/k/v/g; the decay w
keeps its full data-dependent low-rank path (the core of RWKV-6).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparse_update import smm
from repro.models.common import dense_init, last_valid, row_matmul
from repro.models.layers import apply_norm, init_norm
from repro import sharding as SH

CHUNK = 32
DECAY_LORA = 64


def num_heads(cfg) -> int:
    return cfg.d_model // cfg.rwkv.head_dim


def init_time_mix(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = num_heads(cfg)
    ks = jax.random.split(key, 9)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),  # r,k,v,g,w shifts
        "wr": dense_init(ks[1], (d, d), dtype=dtype),
        "wk": dense_init(ks[2], (d, d), dtype=dtype),
        "wv": dense_init(ks[3], (d, d), dtype=dtype),
        "wg": dense_init(ks[4], (d, d), dtype=dtype),
        "wo": dense_init(ks[5], (d, d), dtype=dtype),
        # data-dependent decay lora: w_t = w0 + tanh(x_w @ A) @ B
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": dense_init(ks[6], (d, DECAY_LORA), dtype=jnp.float32),
        "wB": dense_init(ks[7], (DECAY_LORA, d), dtype=jnp.float32, scale=0.1),
        "u": (jax.random.normal(ks[8], (h, hd), jnp.float32) * 0.1),
        "ln_x": init_norm(jax.random.PRNGKey(0), d, "layernorm", jnp.float32),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros or `last` at t=0)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :] if last.ndim == 2 else last
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _wkv_chunk(u, carry, chunk):
    """carry: S [B,H,D,D]; chunk: r,k,v [B,Q,H,D], w [B,Q,H,D] (decay)."""
    s = carry
    r, k, v, w = chunk

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                    # [B,H,D]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,D,D]
        y = jnp.einsum("bhd,bhde->bhe", rt, u[None, :, :, None] * kt[..., :, None]
                       * vt[..., None, :] + s)
        s = wt[..., :, None] * s + kv
        return s, y

    s, ys = jax.lax.scan(step, s, (r.swapaxes(0, 1), k.swapaxes(0, 1),
                                   v.swapaxes(0, 1), w.swapaxes(0, 1)))
    return s, ys.swapaxes(0, 1)                  # [B,Q,H,D]


def wkv(r, k, v, w, u, s0):
    """r,k,v,w: [B,S,H,D] fp32; s0: [B,H,D,D] -> (y [B,S,H,D], s_last)."""
    b, s, h, d = r.shape
    q = min(CHUNK, s)
    assert s % q == 0
    nc = s // q
    resh = lambda t: t.reshape(b, nc, q, h, d).swapaxes(0, 1)
    body = jax.checkpoint(partial(_wkv_chunk, u))
    s_last, ys = jax.lax.scan(body, s0, (resh(r), resh(k), resh(v), resh(w)))
    return ys.swapaxes(0, 1).reshape(b, s, h, d), s_last


def apply_time_mix(p, cfg, x, sel=None, cache=None, length=None):
    """x: [B,S,d]. cache (decode): {"s": [B,H,D,D], "last": [B,d]}.

    length [B] (cached path, None = all s): valid tokens per row. Padded
    rows must not advance the wkv state — their decay is forced to 1 and
    their key to 0 (S_t = 1·S + 0), and the token-shift "last" is taken at
    the per-row valid end, so the cache comes back exactly as after the
    valid prefix."""
    b, s, d = x.shape
    hd = cfg.rwkv.head_dim

    # Serve-mesh detection: the time-mix mats arrive head-block sharded only
    # when H % shards == 0 (a partial head cannot straddle shards — the wkv
    # scan is head-local); otherwise they stay replicated and this whole
    # path is the single-device one.
    ax = SH.current_mapped_axis()
    d_loc = p["wr"].shape[-1]
    local = ax is not None and d_loc != d
    shard = jax.lax.axis_index(ax) if local else None

    last = cache["last"] if cache is not None else None
    xp = _shift(x, last)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = [x + (xp - x) * mu[i] for i in range(5)]

    # column-parallel projections: local head block [B, S, d/n]
    r = smm(xr, p["wr"], sel, "wr").reshape(b, s, -1, hd)
    k = smm(xk, p["wk"], sel, "wk").reshape(b, s, -1, hd)
    v = smm(xv, p["wv"], sel, "wv").reshape(b, s, -1, hd)
    g = smm(xg, p["wg"], sel, "wg")

    # decay lora: wA replicated (tiny), w0/wB sharded with the head block
    wlog = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, -1, hd)         # decay in (0,1)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    if length is not None and s > 1:
        valid = (jnp.arange(s)[None, :] < length[:, None])[:, :, None, None]
        k32 = jnp.where(valid, k32, 0.0)      # kv outer product vanishes
        w = jnp.where(valid, w, 1.0)          # identity decay: S frozen
    h_eff = r.shape[2]
    if cache is None:
        s0 = jnp.zeros((b, h_eff, hd, hd), jnp.float32)
    elif local:
        # the wkv state enters the shard_map replicated: run the scan on
        # this shard's head block only
        s0 = jax.lax.dynamic_slice_in_dim(cache["s"], shard * h_eff, h_eff,
                                          axis=1)
    else:
        s0 = cache["s"]
    if s == 1:  # decode fast path
        s_new, y = _wkv_chunk(p["u"], s0, (r32, k32, v32, w))
    else:
        y, s_new = wkv(r32, k32, v32, w, p["u"], s0)

    if local:
        # ln_x normalizes over the FULL d: gather the head blocks (exact —
        # per-head values are concatenated in shard order)
        y = SH.all_gather_mapped(y, axis=2)
        if cache is not None:
            s_new = SH.all_gather_mapped(s_new, axis=1)
    y = apply_norm(p["ln_x"], y.reshape(b, s, d).astype(x.dtype))
    if local:
        # gate with the local g slice and feed wo row-parallel: one psum
        # reassembles the output
        y_loc = jax.lax.dynamic_slice_in_dim(y, shard * d_loc, d_loc, -1)
        out = jax.lax.psum(smm(y_loc * jax.nn.silu(g), p["wo"], sel, "wo"),
                           ax)
    else:
        y = y * jax.nn.silu(g)
        out = smm(y, p["wo"], sel, "wo")
    new_cache = None if cache is None else {"s": s_new,
                                            "last": last_valid(x, length)}
    return out, new_cache


def init_channel_mix(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32),  # k,r shifts
        "wk": dense_init(ks[1], (d, ff), dtype=dtype),
        "wv": dense_init(ks[2], (ff, d), dtype=dtype),
        "wr": dense_init(jax.random.fold_in(key, 7), (d, d), dtype=dtype),
    }


def apply_channel_mix(p, cfg, x, sel=None, cache=None, length=None):
    b, s, d = x.shape
    last = cache["last"] if cache is not None else None
    xp = _shift(x, last)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    # channel-mix is mlp-shaped: wk column-parallel on ff, wv row-parallel
    # (one psum); wr is [d, d] and stays replicated (specs.py _RWKV_CHAN)
    k = jax.nn.relu(smm(xk, p["wk"], sel, "wk"))
    k = k * k
    kv = row_matmul(k, p["wv"], sel, "wv", full_in=cfg.d_ff)
    out = jax.nn.sigmoid(smm(xr, p["wr"], sel, "wr")) * kv
    new_cache = None if cache is None else {"last": last_valid(x, length)}
    return out, new_cache


def init_rwkv_cache(cfg, batch: int, dtype):
    hd = cfg.rwkv.head_dim
    h = num_heads(cfg)
    return {
        "time": {"s": jnp.zeros((batch, h, hd, hd), jnp.float32),
                 "last": jnp.zeros((batch, cfg.d_model), dtype)},
        "chan": {"last": jnp.zeros((batch, cfg.d_model), dtype)},
    }


def rwkv_snapshot_leaves(cfg, dtype):
    """Per-row (shape, dtype) spec of the rwkv6 recurrent state — the wkv
    matrix state S plus the token-shift `last` vectors — as a prefix-cache
    snapshot."""
    hd = cfg.rwkv.head_dim
    h = num_heads(cfg)
    dt = jnp.dtype(dtype)
    return {"time": {"s": ((h, hd, hd), jnp.float32),
                     "last": ((cfg.d_model,), dt)},
            "chan": {"last": ((cfg.d_model,), dt)}}
