"""Serving paths: prefill (full prompt -> cache + last logits) and
single-token decode against per-layer caches, for every model family.

Caches are pytrees stacked along the segment scan axis so decode is also a
lax.scan over layers (carry = hidden state, xs = (params, cache_in),
ys = cache_out).

Sliding-window attention layers keep ring-buffer caches of size `window`
(gemma local layers cache 1024 slots even at 500k context). SSM layers
(mamba/rwkv) cache O(1) recurrent state, which keeps long_500k runnable
for ssm/hybrid/local archs — see DESIGN §Arch-applicability.

Cache families and prefix reuse
-------------------------------
Every mixer's serve cache plays one of three roles (`_paged_layout`):
`paged` (window-free attention — token rows live in shared page pools),
`ring` (sliding-window attention — per-slot ring buffers), and `state`
(mamba/rwkv — per-slot O(1) recurrent state). ALL THREE participate in
prompt-prefix reuse, each through its family's unit of reuse
(`CACHE_FAMILIES`):

- paged layers share their token pages directly (refcounts + COW in
  `serve/paging.py`) — reuse is position-addressed, any page boundary.
- ring and state layers are NOT position-addressed, so their unit of
  reuse is a *snapshot*: the per-row cache leaves (`snapshot_leaves`)
  copied to host at a page-aligned prefill boundary and restored by
  `cache_insert_row` at admission. A restored snapshot is bit-exact
  because chunked prefill always advances in page-sized steps from
  position 0 — identical prefixes replay identical chunk boundaries.

`cache_extract_row` / `cache_insert_row` are the family-uniform
snapshot/restore ops: they tree-map over whatever leaves a family keeps,
so the prefix cache never inspects family internals. `has_state_layers`
tells the engine whether a config needs snapshots at all;
`snapshot_row_bytes` prices one snapshot for budget accounting.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models import transformer as T
from repro.models.common import last_valid, vocab_parallel_logits
from repro import sharding as SH
from repro.sharding import constrain


def _cache_dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _kv_cache_spec(cfg, batch, seq_len, window):
    return L.init_kv_cache(cfg, batch, seq_len, window=window,
                           dtype=_cache_dtype(cfg))


def _step_cache(cfg, kind: str, batch: int, seq_len: int):
    dt = _cache_dtype(cfg)
    if kind == "dense":
        window = T._window_for(cfg, "dense", 0)
        return _kv_cache_spec(cfg, batch, seq_len, window)
    if kind == "moe":
        return _kv_cache_spec(cfg, batch, seq_len, 0)
    if kind == "gemma_super":
        _, l, g = cfg.attn_pattern.split(":")
        period = int(l) + int(g)
        return {f"sub{i}": _kv_cache_spec(cfg, batch, seq_len,
                                          T._window_for(cfg, "gemma_super", i))
                for i in range(period)}
    if kind == "jamba_super":
        period = cfg.attn_every
        attn_pos = period // 2
        out = {}
        for i in range(period):
            if i == attn_pos:
                out[f"sub{i}"] = _kv_cache_spec(cfg, batch, seq_len, 0)
            else:
                out[f"sub{i}"] = M.init_mamba_cache(cfg, batch, dt)
        return out
    if kind == "rwkv":
        return R.init_rwkv_cache(cfg, batch, dt)
    raise ValueError(kind)


def init_cache(cfg, batch: int, seq_len: int):
    """Stacked caches per segment (leading axis = scan steps)."""
    cache = {}
    for seg in T.segment_layout(cfg):
        one = _step_cache(cfg, seg.kind, batch, seq_len)
        cache[seg.name] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (seg.steps,) + a.shape), one)
    return cache


# ---------------------------------------------------------------------------
# cache row ops (continuous batching)
#
# Every cache leaf — dense KV k/v, ring-buffer k/v, per-row pos, mamba
# h/conv state, rwkv s/last state — is shaped (scan_steps, batch, ...), so a
# decode *slot* is batch row `i` of every leaf. The serving engine re-prefills
# a finished slot from the queue by running a batch=1 prefill and splicing the
# resulting row into the live batch cache; both ops are pure tree-maps over
# fixed shapes and stay inside a single jitted step (`row` may be traced).
# ---------------------------------------------------------------------------

def cache_extract_row(cache, row):
    """Slice batch row `row` out of every leaf, keeping a batch dim of 1."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1), cache)


def cache_insert_row(cache, row_cache, row):
    """Write a batch=1 cache (e.g. from a batch=1 prefill) into batch row
    `row` of every leaf. Overwrites the row completely — k/v (ring caches
    included: prefill zero-fills unused ring slots), recurrent state, and
    pos — so a dirty slot left by a finished request is fully recycled."""
    def ins(dst, src):
        # a smaller update would silently partial-write the row
        assert src.shape[1] == 1 and src.shape[0] == dst.shape[0] \
            and src.shape[2:] == dst.shape[2:], (src.shape, dst.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), row, axis=1)
    return jax.tree.map(ins, cache, row_cache)


def cache_reset_row(cache, row):
    """Zero batch row `row` of every leaf (slot back to its init state)."""
    def rst(a):
        zero = jnp.zeros((a.shape[0], 1) + a.shape[2:], a.dtype)
        return jax.lax.dynamic_update_slice_in_dim(a, zero, row, axis=1)
    return jax.tree.map(rst, cache)


# ---------------------------------------------------------------------------
# paged serving caches
#
# The serve engine splits per-layer caches into two trees:
#
# - `state`: per-slot leaves, shaped [scan_steps, B, ...] — ring-buffer k/v
#   for sliding-window layers and O(1) recurrent state for ssm/rwkv layers
#   (a state family is effectively a single resident "page" per slot). The
#   row ops above (insert/extract/reset) apply unchanged.
# - `pools`: physical token-row pools for window-free attention layers,
#   shaped [scan_steps, num_pages * page_size, Hkv, D] and shared by ALL
#   slots; a per-slot page table maps logical page -> physical page in every
#   layer's pool simultaneously (one page id indexes all layers).
#
# `paged_step` consumes both trees for s >= 1 tokens per row, so the same
# jitted function serves batched decode (B=num_slots, S=1) and chunked
# prefill (B=1, S=page-sized chunk).
# ---------------------------------------------------------------------------

def _paged_layout(cfg, kind: str):
    """(sub_name | None, 'paged'|'ring'|'state') for each sublayer mixer."""
    if kind in ("dense", "moe"):
        window = T._window_for(cfg, kind, 0) if kind == "dense" else 0
        return [(None, "ring" if window > 0 else "paged")]
    if kind == "gemma_super":
        _, l, g = cfg.attn_pattern.split(":")
        out = []
        for i in range(int(l) + int(g)):
            window = T._window_for(cfg, "gemma_super", i)
            out.append((f"sub{i}", "ring" if window > 0 else "paged"))
        return out
    if kind == "jamba_super":
        attn_pos = cfg.attn_every // 2
        return [(f"sub{i}", "paged" if i == attn_pos else "state")
                for i in range(cfg.attn_every)]
    if kind == "rwkv":
        return [(None, "state")]
    raise ValueError(kind)


def has_paged_layers(cfg) -> bool:
    return any(role == "paged"
               for seg in T.segment_layout(cfg)
               for _, role in _paged_layout(cfg, seg.kind))


def has_state_layers(cfg) -> bool:
    """True when any mixer keeps non-position-addressed cache (ring or
    recurrent state) — prefix reuse for these configs needs recurrent-state
    snapshots at page boundaries, not just shared pages."""
    return any(role != "paged"
               for seg in T.segment_layout(cfg)
               for _, role in _paged_layout(cfg, seg.kind))


class CacheFamily:
    """One cache role's contract with the prefix-reuse stack: what its
    per-row reuse unit looks like. `snapshot_leaves(cfg, kind, sub, max_len,
    dtype)` returns a nested dict of (shape, dtype) specs — the leaves
    `cache_extract_row` yields for one slot of this family (empty for
    `paged`, whose unit of reuse is the shared page itself). Snapshot and
    restore are family-uniform (`cache_extract_row`/`cache_insert_row`
    tree-map over the live leaves), so this protocol only *prices and
    describes* the blob; it never moves data."""

    def __init__(self, role: str, leaves):
        self.role = role
        self._leaves = leaves

    def snapshot_leaves(self, cfg, kind: str, sub: int, max_len: int, dtype):
        return self._leaves(cfg, kind, sub, max_len, dtype)


CACHE_FAMILIES = {
    "paged": CacheFamily("paged", lambda cfg, kind, sub, max_len, dt: {}),
    "ring": CacheFamily(
        "ring", lambda cfg, kind, sub, max_len, dt:
        L.ring_snapshot_leaves(cfg, T._window_for(cfg, kind, sub), max_len,
                               dtype=dt)),
    "state": CacheFamily(
        "state", lambda cfg, kind, sub, max_len, dt:
        R.rwkv_snapshot_leaves(cfg, dt) if kind == "rwkv"
        else M.mamba_snapshot_leaves(cfg, dt)),
}


def snapshot_row_bytes(cfg, max_len: int) -> int:
    """Host bytes of ONE slot's recurrent-state snapshot (every non-paged
    mixer's leaves across all scan steps) — the budget unit for the prefix
    cache's snapshot LRU."""
    dt = _cache_dtype(cfg)
    total = 0
    for seg in T.segment_layout(cfg):
        for i, (_, role) in enumerate(_paged_layout(cfg, seg.kind)):
            leaves = CACHE_FAMILIES[role].snapshot_leaves(
                cfg, seg.kind, i, max_len, dt)
            for shape, leaf_dt in jax.tree.leaves(
                    leaves, is_leaf=lambda x: isinstance(x, tuple)
                    and len(x) == 2 and isinstance(x[0], tuple)):
                total += seg.steps * int(np.prod(shape)) \
                    * jnp.dtype(leaf_dt).itemsize
    return total


def _serve_leaf(cfg, role: str, batch: int, max_len: int, kind: str,
                sub: int, pool_rows: int):
    dt = _cache_dtype(cfg)
    if role == "ring":
        hd = cfg.resolved_head_dim
        window = T._window_for(cfg, kind, sub)
        size = min(window, max_len)
        state = {"k": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
                 "v": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt)}
        return state, {}
    if role == "paged":
        hd = cfg.resolved_head_dim
        pool = {"k": jnp.zeros((pool_rows, cfg.num_kv_heads, hd), dt),
                "v": jnp.zeros((pool_rows, cfg.num_kv_heads, hd), dt)}
        return {}, pool
    if kind == "rwkv":
        return R.init_rwkv_cache(cfg, batch, dt), {}
    return M.init_mamba_cache(cfg, batch, dt), {}


def init_serve_cache(cfg, batch: int, max_len: int, num_pages: int,
                     page_size: int):
    """Returns (state, pools): per-slot state tree + shared page pools."""
    pool_rows = num_pages * page_size
    state, pools = {}, {}
    for seg in T.segment_layout(cfg):
        st_one, pl_one = {}, {}
        for i, (sub, role) in enumerate(_paged_layout(cfg, seg.kind)):
            s, p = _serve_leaf(cfg, role, batch, max_len, seg.kind, i,
                               pool_rows)
            if sub is None:
                st_one, pl_one = s, p
            else:           # keep tree structures minimal: no empty subdicts
                if s:
                    st_one[sub] = s
                if p:
                    pl_one[sub] = p
        stack = lambda one: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (seg.steps,) + a.shape), one)
        state[seg.name] = stack(st_one)
        pools[seg.name] = stack(pl_one)
    return state, pools


def copy_pool_rows(pools, src_row, dst_row, n: int):
    """Copy `n` physical token rows src -> dst in EVERY layer's pool (the
    device half of a COW split or prefix-page duplication)."""
    def cp(a):
        rows = jax.lax.dynamic_slice_in_dim(a, src_row, n, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(a, rows, dst_row, axis=1)
    return jax.tree.map(cp, pools)


def read_pool_rows(pools, src_row, n: int):
    """Slice `n` physical token rows out of EVERY layer's pool — the device
    half of spilling an evicted prefix page to the host tier."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, src_row, n, axis=1), pools)


def write_pool_rows(pools, rows, dst_row):
    """Write a `read_pool_rows`-shaped tree back into EVERY layer's pool at
    physical row `dst_row` — the device half of rehydrating a spilled page."""
    return jax.tree.map(
        lambda a, r: jax.lax.dynamic_update_slice_in_dim(
            a, r.astype(a.dtype), dst_row, axis=1), pools, rows)


def _delta_sub(delta, *path):
    """Slice a per-layer delta tree ({"idx": ..., "val": ...}, leaves keyed
    by the same sublayer path as the params) down to one sublayer's
    {leaf -> array} dicts; None when that sublayer carries no delta."""
    if delta is None:
        return None
    idx, val = delta["idx"], delta["val"]
    for name in path:
        if not isinstance(idx, dict) or name not in idx:
            return None
        idx, val = idx[name], val[name]
    if not idx:
        return None
    return {"idx": idx, "val": val}


def _paged_block(cfg, kind: str, p, x, start, active, length, st_c, pl_c,
                 page_table, page_size: int, delta=None,
                 flash_decode: bool = False):
    """One scan step of `paged_step`; mirrors `_decode_block` for s >= 1.

    `delta` carries this layer's per-batch-row compact weight deltas (see
    `repro.core.delta`); covered attention/MLP projections apply them as a
    gather-add at matmul time."""
    def attn(sub_p, h, role, window, st, pl, d=None):
        if role == "ring":
            return L.chunk_ring_attention(sub_p, cfg, h, start, active, st,
                                          window=window, length=length,
                                          delta=d)
        a, pool = L.chunk_paged_attention(sub_p, cfg, h, start, active, pl,
                                          page_table, page_size=page_size,
                                          length=length, delta=d,
                                          flash_decode=flash_decode)
        return a, pool

    if kind in ("dense", "moe"):
        window = T._window_for(cfg, kind, 0) if kind == "dense" else 0
        role = "ring" if window > 0 else "paged"
        h = L.apply_norm(p["attn_ln"], x)
        a, c_out = attn(p["attn"], h, role, window, st_c, pl_c,
                        _delta_sub(delta, "attn"))
        x = x + a
        h = L.apply_norm(p["mlp_ln"], x)
        if kind == "moe":
            y, _ = MOE.apply_moe(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], cfg, h, delta=_delta_sub(delta, "mlp"))
        x = x + y
        return (x, c_out, {}) if role == "ring" else (x, {}, c_out)
    if kind == "gemma_super":
        new_st, new_pl = {}, {}
        for i, (sub, role) in enumerate(_paged_layout(cfg, kind)):
            sp = p[sub]
            window = T._window_for(cfg, kind, i)
            h = L.apply_norm(sp["attn_ln"], x)
            a, c_out = attn(sp["attn"], h, role, window,
                            st_c.get(sub), pl_c.get(sub),
                            _delta_sub(delta, sub, "attn"))
            if role == "ring":
                new_st[sub] = c_out
            else:
                new_pl[sub] = c_out
            x = x + a
            h = L.apply_norm(sp["mlp_ln"], x)
            x = x + L.apply_mlp(sp["mlp"], cfg, h,
                                delta=_delta_sub(delta, sub, "mlp"))
        return x, new_st, new_pl
    if kind == "jamba_super":
        attn_pos = cfg.attn_every // 2
        new_st, new_pl = {}, {}
        for i in range(cfg.attn_every):
            sub = f"sub{i}"
            sp = p[sub]
            h = L.apply_norm(sp["mixer_ln"], x)
            if i == attn_pos:
                a, new_pl[sub] = attn(sp["attn"], h, "paged", 0, None,
                                      pl_c[sub],
                                      _delta_sub(delta, sub, "attn"))
                x = x + a
            else:
                y, new_st[sub] = M.apply_mamba(sp["mamba"], cfg, h,
                                               cache=st_c[sub], length=length)
                x = x + y
            h = L.apply_norm(sp["ffn_ln"], x)
            if T._moe_at(cfg, i):
                y, _ = MOE.apply_moe(sp["moe"], cfg, h)
            else:
                y = L.apply_mlp(sp["mlp"], cfg, h,
                                delta=_delta_sub(delta, sub, "mlp"))
            x = x + y
        return x, new_st, new_pl
    if kind == "rwkv":
        h = L.apply_norm(p["time_ln"], x)
        y, tc = R.apply_time_mix(p["time"], cfg, h, cache=st_c["time"],
                                 length=length)
        x = x + y
        h = L.apply_norm(p["chan_ln"], x)
        y, cc = R.apply_channel_mix(p["chan"], cfg, h, cache=st_c["chan"],
                                    length=length)
        return x + y, {"time": tc, "chan": cc}, {}
    raise ValueError(kind)


def paged_step(cfg, params, batch, state, pools, page_table, *,
               page_size: int, deltas=None, flash_decode: bool = False):
    """s >= 1 tokens per batch row against the paged serve caches.

    batch: {"tokens" [B,S] | "embeds" [B,S,d], "start" [B], "active" [B],
    "length" [B] (optional, default S)}. `start` is the per-row token count
    already cached (the chunk occupies positions start..start+length); rows
    with active=False keep ALL their state (per-row leaves are row-selected
    here, pool writes are dropped inside the attention). `length` lets the
    engine pad every prefill chunk to one fixed page-sized shape — a single
    trace for all prompt lengths — with padded positions (j >= length)
    contributing nothing: cache/pool writes dropped, recurrent state
    frozen, and the returned logits taken at each row's position length-1.

    `deltas` (optional) is {seg_name: {"idx": ..., "val": ...}} of per-user
    compact weight deltas whose leaves are [scan_steps, B, ...] — they ride
    the layer scan next to the params, and each batch row applies its own
    delta as a gather-add inside the covered matmuls. Zero rows are exact
    no-ops, so one trace serves personalized and plain rows alike; the
    engine passes a fixed structure (or None) so the trace count is
    unchanged vs. non-personalized serving.
    Returns (last-valid-position logits [B, V], state, pools).
    """
    start = batch["start"]
    active = batch["active"]
    length = batch.get("length")
    pair = (params, None)
    x = T.embed_tokens(cfg, pair, batch)

    def merge(new, old):
        return jax.tree.map(
            lambda n, o: jnp.where(
                active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, old)

    new_state, new_pools = {}, {}
    for seg in T.segment_layout(cfg):
        stack = params["segments"][seg.name]
        d_seg = None if deltas is None else deltas.get(seg.name)

        def body(x, xs, d_seg=d_seg):
            if d_seg is None:
                p_l, st_l, pl_l = xs
                d_l = None
            else:
                p_l, st_l, pl_l, d_l = xs
            x = constrain(x, "batch", "seq", "model_d")
            x, st_out, pl_out = _paged_block(
                cfg, seg.kind, p_l, x, start, active, length, st_l, pl_l,
                page_table, page_size, delta=d_l, flash_decode=flash_decode)
            return x, (merge(st_out, st_l), pl_out)

        xs = (stack, state[seg.name], pools[seg.name])
        if d_seg is not None:
            xs = xs + (d_seg,)
        x, (new_state[seg.name], new_pools[seg.name]) = jax.lax.scan(
            body, x, xs)
    x = L.apply_norm(T._pick(params, None, "final_norm"), x)
    # each row's last VALID position (prefill chunks are padded)
    x_last = last_valid(x, length)
    w_head = T.lm_head_weight(cfg, pair)
    # vocab-parallel on the serve mesh: local [B, V/n] einsum + all_gather
    logits = vocab_parallel_logits(x_last, w_head, cfg.vocab_size)
    return logits, new_state, new_pools


# ---------------------------------------------------------------------------
# sharded serving: paged_step through shard_map over the model axis
#
# Page pools shard over KV heads (logical axis "paged_pool" -> model); page
# tables, batch rows, and per-slot recurrent/ring state stay replicated
# ("page_table" -> None; state shards slice their block in and all_gather it
# back out). EVERY weight matmul in the step is tensor-parallel whenever its
# sharded dim divides the mesh: attention (paged AND ring) runs
# head-parallel (wq/wk/wv by output head blocks, wo by input rows, one psum
# after wo), MLPs and MoE expert FFNs split d_ff column/row-parallel with
# one psum after w_down, mamba splits d_inner (in/x/out projections
# row-parallel), rwkv time-mix splits by head block and channel-mix by d_ff,
# and the embedding/LM head are vocab-parallel. The tiny remainder — norms,
# routers, decay loras — is replicated. Detection is SHAPE-BASED at every
# site: `paged_param_specs` only shards dims divisible by the mesh size, and
# model code compares the local leaf shape against the full dim, so an
# indivisible group silently falls back to the replicated single-device path
# (and the replication audit's allowlist matches by construction).
#
# GQA head-block sharding keeps groups aligned: shard i holds q heads
# [i*Hq/n, (i+1)*Hq/n) and kv heads [i*Hkv/n, (i+1)*Hkv/n), Hq/n = g*Hkv/n.
#
# Per-user deltas ride the same step: each delta leaf stays replicated and
# the col/row_matmul sites apply it only on the shard owning the selected
# block (column-parallel) or slice its d_in rows before the psum
# (row-parallel) — bit-identical to the single-device gather-add.
# ---------------------------------------------------------------------------

def validate_pool_sharding(cfg, rules) -> int:
    """Number of model-axis shards the page pools will split into; raises
    with a clear message when the head counts cannot shard that many ways
    (silent mis-sharding would desync pools from their replicated page
    tables)."""
    if rules is None or rules.model_axis is None:
        return 1
    with SH.use_rules(rules):
        n = SH.model_axis_size()     # raises if rules carry no mesh
    if n == 1 or not has_paged_layers(cfg):
        return n
    if cfg.num_kv_heads % n != 0:
        raise ValueError(
            f"cannot shard page pools {n}-way over the model axis: "
            f"num_kv_heads={cfg.num_kv_heads} is not divisible by the "
            f"model-axis size {n} (pool leaves are [rows, Hkv, head_dim])")
    if cfg.num_heads % n != 0:
        raise ValueError(
            f"cannot shard paged attention {n}-way over the model axis: "
            f"num_heads={cfg.num_heads} is not divisible by the "
            f"model-axis size {n}")
    return n


def pool_pspec(rules):
    """PartitionSpec of every page-pool leaf [steps, rows, Hkv, head_dim]
    under `rules` — the "paged_pool" logical rule on the KV-head axis.
    Returned in jax's NORMALIZED form (trailing Nones stripped, size-1 mesh
    axes dropped): sharding equality — and therefore the jitted step's
    dispatch cache key — compares normalized specs, so pinning pools to any
    other spelling would make the first call key a duplicate entry."""
    from jax.sharding import PartitionSpec as P
    ax = rules.rules.get("paged_pool")
    if ax is not None and rules.mesh is not None \
            and rules.mesh.shape.get(ax, 1) == 1:
        ax = None
    return P() if ax is None else P(None, None, ax)


def paged_param_specs(cfg, params, rules):
    """PartitionSpec tree for serve params: every matmul weight shards over
    the model axis when its sharded dim divides the mesh size (attention by
    head block, MLP/MoE/rwkv-channel by d_ff, mamba by d_inner, rwkv
    time-mix by head block, embed/LM head by vocab); norms, routers, and any
    group failing its divisibility check stay replicated — model code
    detects the fallback from the leaf shapes. Segment leaves carry a
    leading scan-steps axis; embed/lm_head do not."""
    from jax.sharding import PartitionSpec as P
    axis = rules.model_axis
    n = rules.mesh.shape[axis] if (rules.mesh is not None and axis) else 1
    specs = jax.tree.map(lambda _: P(), params)

    def set_group(ts, name, spec):
        # overwrite only the named leaves; nested dicts (ln_x, shared)
        # keep their already-replicated structure
        if spec is None or name not in ts:
            return
        for k, v in spec.items():
            if k in ts[name]:
                ts[name][k] = v

    heads_ok = cfg.num_heads % n == 0 and cfg.num_kv_heads % n == 0
    attn_spec = {"wq": P(None, None, axis), "wk": P(None, None, axis),
                 "wv": P(None, None, axis), "wo": P(None, axis, None)}

    def mlp_spec(p_mlp):
        if p_mlp["w_up"].shape[-1] % n:
            return None
        return {"w_gate": P(None, None, axis), "w_up": P(None, None, axis),
                "w_down": P(None, axis, None)}

    mamba_ok = M.d_inner(cfg) % n == 0 and cfg.d_model % n == 0 \
        if cfg.ssm is not None else False
    mamba_spec = {"in_proj": P(None, axis, None), "conv_w": P(None, None, axis),
                  "conv_b": P(None, axis), "x_proj": P(None, axis, None),
                  "dt_proj": P(None, None, axis), "dt_bias": P(None, axis),
                  "A_log": P(None, axis, None), "D": P(None, axis),
                  "out_proj": P(None, axis, None)}
    rwkv_ok = cfg.rwkv is not None and R.num_heads(cfg) % n == 0
    time_spec = {"wr": P(None, None, axis), "wk": P(None, None, axis),
                 "wv": P(None, None, axis), "wg": P(None, None, axis),
                 "wo": P(None, axis, None), "w0": P(None, axis),
                 "wB": P(None, None, axis), "u": P(None, axis, None)}
    chan_spec = {"wk": P(None, None, axis), "wv": P(None, axis, None)} \
        if cfg.d_ff % n == 0 else None

    for seg in T.segment_layout(cfg):
        seg_p = params["segments"][seg.name]
        seg_s = specs["segments"][seg.name]
        for sub, role in _paged_layout(cfg, seg.kind):
            tp = seg_p if sub is None else seg_p[sub]
            ts = seg_s if sub is None else seg_s[sub]
            if "attn" in tp and (role == "paged" or heads_ok):
                # paged layers are validated divisible up front
                set_group(ts, "attn", attn_spec)
            if "mamba" in tp and mamba_ok:
                set_group(ts, "mamba", mamba_spec)
            if "time" in tp and rwkv_ok:
                set_group(ts, "time", time_spec)
            if "chan" in tp:
                set_group(ts, "chan", chan_spec)
            if "mlp" in tp:
                set_group(ts, "mlp", mlp_spec(tp["mlp"]))
            if "moe" in tp:
                if cfg.d_ff % n == 0:
                    set_group(ts, "moe", {
                        "w_gate": P(None, None, None, axis),
                        "w_up": P(None, None, None, axis),
                        "w_down": P(None, None, axis, None)})
                if "shared" in tp["moe"]:
                    sh = mlp_spec(tp["moe"]["shared"])
                    if sh is not None:
                        set_group(ts["moe"], "shared", sh)
    if cfg.vocab_size % n == 0:
        if "embed" in specs:
            specs["embed"]["tok"] = P(axis, None)
        if "lm_head" in specs:
            specs["lm_head"]["w"] = P(None, axis)
    return specs


def sharded_param_shapes(cfg, params, rules):
    """(forbidden, replicated) full per-matmul shapes for the replication
    audit. `forbidden` holds the FULL (unsharded) shape of every
    spec-sharded leaf — a dot_general consuming such a shape inside the
    sharded step means the leaf arrived replicated and the per-shard FLOP
    saving silently reverted. Segment leaves drop their leading scan-steps
    axis (the scan body consumes per-step slices). Two collision classes
    are subtracted into the `replicated` allowlist: full shapes that ALSO
    belong to a policy-replicated leaf (e.g. rwkv channel-mix wr [d, d]
    colliding with a sharded time-mix wr), and full shapes coinciding with
    some leaf's POST-SHARD local shape (smoke configs set d_ff = 2 d, so
    the n=2 local w_gate [d, d] is a legitimate matmul that must not match
    a forbidden full wq [d, d])."""
    specs = paged_param_specs(cfg, params, rules)
    axis = rules.model_axis
    n = rules.mesh.shape[axis] if (rules.mesh is not None and axis) else 1
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: x is None)
    forbidden, replicated = set(), set()
    for (path, leaf), spec in zip(flat_p, flat_s):
        keys = [getattr(k, "key", None) for k in path]
        scan = bool(keys) and keys[0] == "segments"
        shape = tuple(leaf.shape)
        local = tuple(d // n if (i < len(spec) and spec[i] is not None)
                      else d for i, d in enumerate(shape))
        if scan:
            shape, local = shape[1:], local[1:]
        if len(shape) < 2:
            continue      # vectors never feed a dot_general contraction
        if any(a is not None for a in spec):
            forbidden.add(shape)
            replicated.add(local)
        else:
            replicated.add(shape)
    return forbidden - replicated, replicated


def make_sharded_paged_step(cfg, rules, params, *, page_size: int,
                            flash_decode: bool = True):
    """Build a jitted `paged_step` that runs through shard_map over
    `rules.model_axis`. Signature matches the single-device step
    (`(params, batch, state, pools, page_table, deltas)`), per-user deltas
    included: delta leaves cross the shard_map replicated and each
    col/row_matmul site applies its shard's share (see the contract comment
    above). The deltas shard_map is built lazily, keyed by the deltas tree
    structure — the engine passes one fixed structure (or always None), so
    the jit trace count stays at one per batch shape, exactly as on a
    single device. `params` is only used for its tree structure/shapes
    (in_specs are a full pytree over the param leaves)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    mesh, axis = rules.mesh, rules.model_axis
    validate_pool_sharding(cfg, rules)
    param_specs = paged_param_specs(cfg, params, rules)
    io_specs = dict(out_specs=(P(), P(), pool_pspec(rules)), check_vma=False)

    def body(p, batch, state, pools, pt, deltas=None):
        # inside shard_map arrays are per-shard locals: GSPMD constraints
        # (use_rules) do not apply, and row-parallel partials psum over
        # `axis`
        with SH.use_rules(None), SH.mapped_model_axis(axis):
            return paged_step(cfg, p, batch, state, pools, pt,
                              page_size=page_size, deltas=deltas,
                              flash_decode=flash_decode)

    base = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, P(), P(), pool_pspec(rules), P()),
        **io_specs))
    delta_steps: dict[Any, Any] = {}

    def call(p, batch, state, pools, pt, deltas=None):
        if deltas is None:
            return base(p, batch, state, pools, pt)
        key = jax.tree.structure(deltas)
        step = delta_steps.get(key)
        if step is None:
            step = jax.jit(shard_map(
                body, mesh=mesh,
                in_specs=(param_specs, P(), P(), pool_pspec(rules), P(),
                          jax.tree.map(lambda _: P(), deltas)),
                **io_specs))
            delta_steps[key] = step
        return step(p, batch, state, pools, pt, deltas)

    def cache_size():
        sizes = [getattr(s, "_cache_size", lambda: -1)()
                 for s in [base] + list(delta_steps.values())]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    call._cache_size = cache_size
    return call


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _decode_block(cfg, kind: str, p, x, positions, cache):
    if kind in ("dense", "moe"):
        h = L.apply_norm(p["attn_ln"], x)
        window = T._window_for(cfg, kind, 0) if kind == "dense" else 0
        a, cache = L.decode_attention(p["attn"], cfg, h, positions, cache,
                                      window=window)
        x = x + a
        h = L.apply_norm(p["mlp_ln"], x)
        if kind == "moe":
            y, _ = MOE.apply_moe(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], cfg, h)
        return x + y, cache
    if kind == "gemma_super":
        _, l, g = cfg.attn_pattern.split(":")
        period = int(l) + int(g)
        new_cache = {}
        for i in range(period):
            sub = p[f"sub{i}"]
            window = T._window_for(cfg, "gemma_super", i)
            h = L.apply_norm(sub["attn_ln"], x)
            a, new_cache[f"sub{i}"] = L.decode_attention(
                sub["attn"], cfg, h, positions, cache[f"sub{i}"], window=window)
            x = x + a
            h = L.apply_norm(sub["mlp_ln"], x)
            x = x + L.apply_mlp(sub["mlp"], cfg, h)
        return x, new_cache
    if kind == "jamba_super":
        period = cfg.attn_every
        attn_pos = period // 2
        new_cache = {}
        for i in range(period):
            sub = p[f"sub{i}"]
            h = L.apply_norm(sub["mixer_ln"], x)
            if i == attn_pos:
                a, new_cache[f"sub{i}"] = L.decode_attention(
                    sub["attn"], cfg, h, positions, cache[f"sub{i}"])
                x = x + a
            else:
                y, new_cache[f"sub{i}"] = M.apply_mamba(
                    sub["mamba"], cfg, h, cache=cache[f"sub{i}"])
                x = x + y
            h = L.apply_norm(sub["ffn_ln"], x)
            if T._moe_at(cfg, i):
                y, _ = MOE.apply_moe(sub["moe"], cfg, h)
            else:
                y = L.apply_mlp(sub["mlp"], cfg, h)
            x = x + y
        return x, new_cache
    if kind == "rwkv":
        h = L.apply_norm(p["time_ln"], x)
        y, tc = R.apply_time_mix(p["time"], cfg, h, cache=cache["time"])
        x = x + y
        h = L.apply_norm(p["chan_ln"], x)
        y, cc = R.apply_channel_mix(p["chan"], cfg, h, cache=cache["chan"])
        return x + y, {"time": tc, "chan": cc}
    raise ValueError(kind)


def decode_step(cfg, params, batch, cache):
    """One token for the whole batch.

    batch: {"tokens" [B,1] | "embeds" [B,1,d], "positions" [B,1] or [3,B,1]}
    Returns (logits [B, V], new_cache).
    """
    pair = (params, None)
    x = T.embed_tokens(cfg, pair, batch)
    positions = batch.get("positions")
    if positions is None:
        raise ValueError("decode_step requires explicit positions")

    new_cache = {}
    for seg in T.segment_layout(cfg):
        stack = params["segments"][seg.name]

        def body(x, xs):
            p_l, c_l = xs
            x = constrain(x, "batch", "seq", "model_d")
            x, c_out = _decode_block(cfg, seg.kind, p_l, x, positions, c_l)
            return x, c_out

        x, new_cache[seg.name] = jax.lax.scan(
            body, x, (stack, cache[seg.name]))
    x = L.apply_norm(T._pick(params, None, "final_norm"), x)
    w_head = T.lm_head_weight(cfg, pair)
    logits = jnp.einsum("bsd,dv->bsv", x, w_head,
                        preferred_element_type=jnp.float32)
    return logits[:, -1], new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch, pad_to: int = 0):
    """Run the full prompt, returning (last-token logits [B, V], cache).

    Attention layers: compute K/V for the whole prompt and write them into
    the cache (ring-layout for windowed layers). SSM layers: run the
    recurrence and keep the final state.
    """
    pair = (params, None)
    x = T.embed_tokens(cfg, pair, batch)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    pad_to = max(pad_to, s)
    cache = {}
    for seg in T.segment_layout(cfg):
        stack = params["segments"][seg.name]

        def body(x, p_l):
            x = constrain(x, "batch", "seq", "model_d")
            x, c_out = _prefill_block(cfg, seg.kind, p_l, x, positions, pad_to)
            return x, c_out

        x, cache[seg.name] = jax.lax.scan(body, x, stack)
    x = L.apply_norm(T._pick(params, None, "final_norm"), x)
    w_head = T.lm_head_weight(cfg, pair)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], w_head,
                        preferred_element_type=jnp.float32)
    return logits, cache


def _ring_pack(k, window: int):
    """Pack the last `window` positions of k [B,S,H,D] into a ring buffer of
    exactly `window` slots (position p lives at slot p % window)."""
    b, s, h, d = k.shape
    out = jnp.zeros((b, window, h, d), k.dtype)
    n = min(s, window)
    tail = k[:, s - n:]
    slots = jnp.arange(s - n, s) % window
    return out.at[:, slots].set(tail)


def _pad_cache(k, pad_to: int):
    b, s, h, d = k.shape
    if pad_to <= s:
        return k
    return jnp.pad(k, ((0, 0), (0, pad_to - s), (0, 0), (0, 0)))


def _prefill_attn(cfg, p, x, positions, window, pad_to):
    b, s, _ = x.shape
    q, k, v = L._qkv(p, cfg, x, positions)
    if s > 2048:
        out = L._sdpa_flash(q, k, v, window)
    else:
        out = L._sdpa_dense(q, k, v, window)
    out = out.reshape(b, s, -1)
    out = jnp.matmul(out, p["wo"])
    if window > 0:
        # cap the ring at the cache capacity: when window >= pad_to the ring
        # never wraps, and init_kv_cache sizes the cache the same way, so
        # prefill rows stay insertable into an init_cache'd batch cache
        w = min(window, pad_to)
        kc = _ring_pack(k, w).astype(_cache_dtype(cfg))
        vc = _ring_pack(v, w).astype(_cache_dtype(cfg))
    else:
        kc = _pad_cache(k, pad_to).astype(_cache_dtype(cfg))
        vc = _pad_cache(v, pad_to).astype(_cache_dtype(cfg))
    cache = {"k": kc, "v": vc, "pos": jnp.full((b,), s, jnp.int32)}
    return out, cache


def _prefill_block(cfg, kind: str, p, x, positions, pad_to):
    if kind in ("dense", "moe"):
        window = T._window_for(cfg, kind, 0) if kind == "dense" else 0
        h = L.apply_norm(p["attn_ln"], x)
        a, cache = _prefill_attn(cfg, p["attn"], h, positions, window, pad_to)
        x = x + a
        h = L.apply_norm(p["mlp_ln"], x)
        if kind == "moe":
            y, _ = MOE.apply_moe(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], cfg, h)
        return x + y, cache
    if kind == "gemma_super":
        _, l, g = cfg.attn_pattern.split(":")
        period = int(l) + int(g)
        caches = {}
        for i in range(period):
            sub = p[f"sub{i}"]
            window = T._window_for(cfg, "gemma_super", i)
            h = L.apply_norm(sub["attn_ln"], x)
            a, caches[f"sub{i}"] = _prefill_attn(cfg, sub["attn"], h,
                                                 positions, window, pad_to)
            x = x + a
            h = L.apply_norm(sub["mlp_ln"], x)
            x = x + L.apply_mlp(sub["mlp"], cfg, h)
        return x, caches
    if kind == "jamba_super":
        period = cfg.attn_every
        attn_pos = period // 2
        caches = {}
        for i in range(period):
            sub = p[f"sub{i}"]
            h = L.apply_norm(sub["mixer_ln"], x)
            if i == attn_pos:
                a, caches[f"sub{i}"] = _prefill_attn(cfg, sub["attn"], h,
                                                     positions, 0, pad_to)
                x = x + a
            else:
                y, state = _mamba_prefill_state(sub["mamba"], cfg, h)
                caches[f"sub{i}"] = state
                x = x + y
            h = L.apply_norm(sub["ffn_ln"], x)
            if T._moe_at(cfg, i):
                y, _ = MOE.apply_moe(sub["moe"], cfg, h)
            else:
                y = L.apply_mlp(sub["mlp"], cfg, h)
            x = x + y
        return x, caches
    if kind == "rwkv":
        h = L.apply_norm(p["time_ln"], x)
        y, ts = _rwkv_prefill_time(p["time"], cfg, h)
        x = x + y
        h = L.apply_norm(p["chan_ln"], x)
        y, _ = R.apply_channel_mix(p["chan"], cfg, h)
        cc = {"last": h[:, -1]}
        return x + y, {"time": ts, "chan": cc}
    raise ValueError(kind)


def _mamba_prefill_state(p, cfg, x):
    """apply_mamba returning the final recurrent state as a cache."""
    b, s, _ = x.shape
    dt = _cache_dtype(cfg)
    out, _ = M.apply_mamba(p, cfg, x)
    # final conv history = last (d_conv-1) post-in_proj activations
    xz = jnp.matmul(x, p["in_proj"])
    x_in = xz[..., : M.d_inner(cfg)]
    conv = x_in[:, -(cfg.ssm.d_conv - 1):]
    pad = cfg.ssm.d_conv - 1 - conv.shape[1]
    if pad > 0:   # prompt shorter than the history: oldest slots stay zero
        conv = jnp.pad(conv, ((0, 0), (pad, 0), (0, 0)))
    # final ssm state: recompute the scan's last carry
    h_last = _mamba_last_state(p, cfg, x)
    return out, {"h": h_last, "conv": conv.astype(dt)}


def _mamba_last_state(p, cfg, x):
    b = x.shape[0]
    xz = jnp.matmul(x, p["in_proj"])
    x_in = xz[..., : M.d_inner(cfg)]
    x_c = jax.nn.silu(M._causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"]))
    dbl = jnp.matmul(x_c, p["x_proj"])
    dr = M.dt_rank(cfg)
    ns = cfg.ssm.d_state
    dtv, b_ssm, c_ssm = jnp.split(dbl, [dr, dr + ns], axis=-1)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                          + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, M.d_inner(cfg), ns), jnp.float32)
    _, h_last = M.selective_scan(a, dtv, x_c.astype(jnp.float32),
                                 b_ssm.astype(jnp.float32),
                                 c_ssm.astype(jnp.float32), h0)
    return h_last


def _rwkv_prefill_time(p, cfg, x):
    y, _ = R.apply_time_mix(p, cfg, x)
    # final state via a dedicated wkv pass
    b, s, d = x.shape
    hd = cfg.rwkv.head_dim
    h = R.num_heads(cfg)
    xp = R._shift(x)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = [x + (xp - x) * mu[i] for i in range(5)]
    r = jnp.matmul(xr, p["wr"]).reshape(b, s, h, hd).astype(jnp.float32)
    k = jnp.matmul(xk, p["wk"]).reshape(b, s, h, hd).astype(jnp.float32)
    v = jnp.matmul(xv, p["wv"]).reshape(b, s, h, hd).astype(jnp.float32)
    wlog = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, h, hd)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, s_last = R.wkv(r, k, v, w, p["u"], s0)
    return y, {"s": s_last, "last": x[:, -1]}
