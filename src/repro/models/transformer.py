"""Decoder-only LM supporting all assigned families:

dense (llama3/command-r/nemotron/musicgen/qwen2-vl), local:global (gemma3),
MoE (deepseek/llama4), hybrid mamba+attn+MoE (jamba), RWKV-6 (rwkv6).

Layout: layers are grouped into SEGMENTS, each a lax.scan over stacked
params (HLO size O(1) in depth). Heterogeneous periods (gemma 5:1, jamba
1:7) scan over *super-blocks* and unroll the period inside the body.

Training params arrive as a (frozen, trainable) pair of same-structure trees
(split along the stacked-layer axis by the sparse-update plan); the frozen
prefix is never differentiated, so XLA saves no residuals for it — the
paper's activation-memory saving.

`sel` carries dynamic channel-block selection indices (see core.sparse_update).
"""
from __future__ import annotations

import dataclasses
import zlib
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models.common import (dense_init, embed_init,
                                 vocab_parallel_gather)
from repro.sharding import constrain

CE_CHUNK = 1024


class SegmentDef(NamedTuple):
    name: str
    steps: int          # scan length
    kind: str           # dense | moe | gemma_super | jamba_super | rwkv
    layers_per_step: int


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def segment_layout(cfg: ModelConfig) -> list[SegmentDef]:
    if cfg.family == "ssm":
        return [SegmentDef("blocks", cfg.num_layers, "rwkv", 1)]
    if cfg.family == "hybrid":
        assert cfg.num_layers % cfg.attn_every == 0
        return [SegmentDef("blocks", cfg.num_layers // cfg.attn_every,
                           "jamba_super", cfg.attn_every)]
    if cfg.attn_pattern.startswith("local_global"):
        _, l, g = cfg.attn_pattern.split(":")
        period = int(l) + int(g)
        n_super = cfg.num_layers // period
        tail = cfg.num_layers - n_super * period
        segs = [SegmentDef("blocks", n_super, "gemma_super", period)]
        if tail:
            segs.append(SegmentDef("tail", tail, "dense", 1))
        return segs
    if cfg.moe is not None and cfg.moe.layout == "all_but_first":
        return [SegmentDef("first", 1, "dense", 1),
                SegmentDef("blocks", cfg.num_layers - 1, "moe", 1)]
    if cfg.moe is not None:
        return [SegmentDef("blocks", cfg.num_layers, "moe", 1)]
    return [SegmentDef("blocks", cfg.num_layers, "dense", 1)]


def _moe_at(cfg, layer_in_period: int) -> bool:
    """For jamba: is the FFN at this in-block index MoE?"""
    if cfg.moe is None:
        return False
    if cfg.moe.layout == "every_2":
        return layer_in_period % 2 == 1
    return True


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg, dtype, d_ff=None):
    k1, k2 = jax.random.split(key)
    return {
        "attn_ln": L.init_norm(key, cfg.d_model, cfg.norm_kind, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp_ln": L.init_norm(key, cfg.d_model, cfg.norm_kind, dtype),
        "mlp": L.init_mlp(k2, cfg, dtype, d_ff=d_ff),
    }


def _init_moe_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_ln": L.init_norm(key, cfg.d_model, cfg.norm_kind, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp_ln": L.init_norm(key, cfg.d_model, cfg.norm_kind, dtype),
        "moe": MOE.init_moe(k2, cfg, dtype),
    }


def _init_jamba_super(key, cfg, dtype):
    """One super-block: `attn_every` sublayers; index attn_every//2 is
    attention, the rest mamba; FFN alternates dense/MoE."""
    out = {}
    period = cfg.attn_every
    attn_pos = period // 2
    ks = jax.random.split(key, period * 2)
    for i in range(period):
        mixer_key, ffn_key = ks[2 * i], ks[2 * i + 1]
        sub = {"mixer_ln": L.init_norm(mixer_key, cfg.d_model, cfg.norm_kind, dtype),
               "ffn_ln": L.init_norm(ffn_key, cfg.d_model, cfg.norm_kind, dtype)}
        if i == attn_pos:
            sub["attn"] = L.init_attention(mixer_key, cfg, dtype)
        else:
            sub["mamba"] = M.init_mamba(mixer_key, cfg, dtype)
        if _moe_at(cfg, i):
            sub["moe"] = MOE.init_moe(ffn_key, cfg, dtype)
        else:
            sub["mlp"] = L.init_mlp(ffn_key, cfg, dtype)
        out[f"sub{i}"] = sub
    return out


def _init_gemma_super(key, cfg, dtype, period: int):
    ks = jax.random.split(key, period)
    return {f"sub{i}": _init_dense_block(ks[i], cfg, dtype) for i in range(period)}


def _init_rwkv_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "time_ln": L.init_norm(k1, cfg.d_model, "layernorm", dtype),
        "time": R.init_time_mix(k1, cfg, dtype),
        "chan_ln": L.init_norm(k2, cfg.d_model, "layernorm", dtype),
        "chan": R.init_channel_mix(k2, cfg, dtype),
    }


def _dense_ff_first(cfg) -> int:
    # deepseek-style dense first layer: ~ (n_routed_active+shared) * d_ff
    return 8 * cfg.d_ff


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    segs = segment_layout(cfg)
    kseg, kemb, khead = jax.random.split(key, 3)
    params: dict[str, Any] = {"segments": {}}

    if not cfg.embed_inputs or cfg.tie_embeddings:
        params["embed"] = {"tok": embed_init(kemb, (cfg.vocab_size, cfg.d_model),
                                             dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(khead, (cfg.d_model, cfg.vocab_size),
                                             dtype=dtype)}
    if cfg.family == "ssm":
        params["ln0"] = L.init_norm(kemb, cfg.d_model, "layernorm", dtype)

    for seg in segs:
        keys = jax.random.split(
            jax.random.fold_in(kseg, zlib.crc32(seg.name.encode()) % 2**31),
            seg.steps)
        if seg.kind == "dense":
            d_ff = _dense_ff_first(cfg) if seg.name == "first" else None
            blocks = [_init_dense_block(k, cfg, dtype, d_ff=d_ff) for k in keys]
        elif seg.kind == "moe":
            blocks = [_init_moe_block(k, cfg, dtype) for k in keys]
        elif seg.kind == "gemma_super":
            blocks = [_init_gemma_super(k, cfg, dtype, seg.layers_per_step)
                      for k in keys]
        elif seg.kind == "jamba_super":
            blocks = [_init_jamba_super(k, cfg, dtype) for k in keys]
        elif seg.kind == "rwkv":
            blocks = [_init_rwkv_block(k, cfg, dtype) for k in keys]
        else:
            raise ValueError(seg.kind)
        params["segments"][seg.name] = jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *blocks)

    params["final_norm"] = L.init_norm(kseg, cfg.d_model,
                                       "layernorm" if cfg.family == "ssm"
                                       else cfg.norm_kind, dtype)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _window_for(cfg, kind: str, sub: int) -> int:
    if kind == "gemma_super":
        _, l, _g = cfg.attn_pattern.split(":")
        return cfg.sliding_window if sub < int(l) else 0
    if kind == "dense" and cfg.attn_pattern.startswith("local_global"):
        return cfg.sliding_window   # gemma tail layers are local
    return 0


def _sub_sel(sel, name):
    """Subset a selection tuple — (idx, spec) or (idx, spec, wsel) — to one
    child subtree. All components share the idx tree's structure."""
    if sel is None:
        return None
    idx = sel[0]
    if idx is None or name not in idx:
        return None
    return tuple(comp[name] for comp in sel)


def _apply_dense_block(cfg, p, x, positions, sel, window: int):
    h = L.apply_norm(p["attn_ln"], x)
    x = x + L.attention(p["attn"], cfg, h, positions, window=window,
                        sel=_sub_sel(sel, "attn"))
    h = L.apply_norm(p["mlp_ln"], x)
    x = x + L.apply_mlp(p["mlp"], cfg, h, sel=_sub_sel(sel, "mlp"))
    return x, jnp.zeros((2,), jnp.float32)


def _apply_moe_block(cfg, p, x, positions, sel):
    h = L.apply_norm(p["attn_ln"], x)
    x = x + L.attention(p["attn"], cfg, h, positions,
                        sel=_sub_sel(sel, "attn"))
    h = L.apply_norm(p["mlp_ln"], x)
    y, aux = MOE.apply_moe(p["moe"], cfg, h, sel=_sub_sel(sel, "moe"))
    x = x + y
    return x, jnp.stack([aux["load_balance"], aux["router_z"]])


def _apply_jamba_super(cfg, p, x, positions, sel):
    period = cfg.attn_every
    attn_pos = period // 2
    aux = jnp.zeros((2,), jnp.float32)
    for i in range(period):
        sub = p[f"sub{i}"]
        ssel = _sub_sel(sel, f"sub{i}")
        h = L.apply_norm(sub["mixer_ln"], x)
        if i == attn_pos:
            x = x + L.attention(sub["attn"], cfg, h, positions,
                                sel=_sub_sel(ssel, "attn"))
        else:
            y, _ = M.apply_mamba(sub["mamba"], cfg, h, sel=_sub_sel(ssel, "mamba"))
            x = x + y
        h = L.apply_norm(sub["ffn_ln"], x)
        if _moe_at(cfg, i):
            y, a = MOE.apply_moe(sub["moe"], cfg, h, sel=_sub_sel(ssel, "moe"))
            aux = aux + jnp.stack([a["load_balance"], a["router_z"]])
        else:
            y = L.apply_mlp(sub["mlp"], cfg, h, sel=_sub_sel(ssel, "mlp"))
        x = x + y
    return x, aux


def _apply_gemma_super(cfg, p, x, positions, sel, period: int):
    for i in range(period):
        sub = p[f"sub{i}"]
        window = _window_for(cfg, "gemma_super", i)
        x, _ = _apply_dense_block(cfg, sub, x, positions,
                                  _sub_sel(sel, f"sub{i}"), window)
    return x, jnp.zeros((2,), jnp.float32)


def _apply_rwkv_block(cfg, p, x, positions, sel):
    h = L.apply_norm(p["time_ln"], x)
    y, _ = R.apply_time_mix(p["time"], cfg, h, sel=_sub_sel(sel, "time"))
    x = x + y
    h = L.apply_norm(p["chan_ln"], x)
    y, _ = R.apply_channel_mix(p["chan"], cfg, h, sel=_sub_sel(sel, "chan"))
    x = x + y
    return x, jnp.zeros((2,), jnp.float32)


def _apply_step(cfg, kind: str, p, x, positions, sel):
    if kind == "dense":
        window = _window_for(cfg, "dense", 0)
        return _apply_dense_block(cfg, p, x, positions, sel, window)
    if kind == "moe":
        return _apply_moe_block(cfg, p, x, positions, sel)
    if kind == "gemma_super":
        _, l, g = cfg.attn_pattern.split(":")
        return _apply_gemma_super(cfg, p, x, positions, sel, int(l) + int(g))
    if kind == "jamba_super":
        return _apply_jamba_super(cfg, p, x, positions, sel)
    if kind == "rwkv":
        return _apply_rwkv_block(cfg, p, x, positions, sel)
    raise ValueError(kind)


def _run_segment(cfg, kind: str, stack, x, positions, sel_idx, sel_spec,
                 remat: bool = True, sel_wsel=None):
    """Scan a segment. sel_idx: stacked [steps, ...] idx tree or None.
    sel_wsel: stacked compact selected-block tree (compact-gradient path)."""
    if stack is None:
        return x, jnp.zeros((2,), jnp.float32)

    def body(carry, xs):
        x, aux = carry
        p_l, idx_l, wsel_l = xs
        if idx_l is None:
            sel = None
        elif wsel_l is None:
            sel = (idx_l, sel_spec)
        else:
            sel = (idx_l, sel_spec, wsel_l)
        x = constrain(x, "batch", "seq", "model_d")
        x, a = _apply_step(cfg, kind, p_l, x, positions, sel)
        return (x, aux + a), None

    fn = jax.checkpoint(body) if remat else body
    steps = jax.tree.leaves(stack)[0].shape[0]
    xs = (stack, sel_idx, sel_wsel)
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((2,), jnp.float32)), xs,
                               length=steps)
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _pick(a, b, *path):
    """Fetch a subtree preferring the trainable tree."""
    for tree in (b, a):
        if tree is None:
            continue
        node = tree
        ok = True
        for key in path:
            if node is None or key not in node:
                ok = False
                break
            node = node[key]
        if ok and node is not None:
            return node
    return None


def embed_tokens(cfg, params_pair, batch):
    frozen, trainable = params_pair
    if cfg.embed_inputs:
        x = batch["embeds"]
    else:
        emb = _pick(frozen, trainable, "embed", "tok")
        # vocab-parallel on the serve mesh (local gather + psum); plain
        # jnp.take otherwise
        x = vocab_parallel_gather(emb, batch["tokens"], cfg.vocab_size)
    if cfg.family == "ssm":
        x = L.apply_norm(_pick(frozen, trainable, "ln0"), x)
    return x


def forward(cfg, params_pair, batch, sel=None, remat: bool = True):
    """params_pair = (frozen_tree, trainable_tree); either may be None.
    batch: {"tokens" [B,S] | "embeds" [B,S,d], optional "positions"}.
    Returns (hidden [B,S,d], aux [2])."""
    frozen, trainable = params_pair
    x = embed_tokens(cfg, params_pair, batch)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    aux = jnp.zeros((2,), jnp.float32)
    for seg in segment_layout(cfg):
        f_stack = _pick(frozen, None, "segments", seg.name)
        t_stack = _pick(trainable, None, "segments", seg.name)
        sel_idx = sel_spec = sel_wsel = None
        if sel is not None and seg.name in sel[0]:
            sel_idx, sel_spec = sel[0][seg.name], sel[1][seg.name]
            if len(sel) > 2 and sel[2] is not None:
                sel_wsel = sel[2].get(seg.name)
        x, a1 = _run_segment(cfg, seg.kind, f_stack, x, positions,
                             None, None, remat)
        x, a2 = _run_segment(cfg, seg.kind, t_stack, x, positions,
                             sel_idx, sel_spec, remat, sel_wsel=sel_wsel)
        aux = aux + a1 + a2
    x = L.apply_norm(_pick(frozen, trainable, "final_norm"), x)
    return x, aux


def lm_head_weight(cfg, params_pair):
    frozen, trainable = params_pair
    if cfg.tie_embeddings:
        return _pick(frozen, trainable, "embed", "tok").T
    return _pick(frozen, trainable, "lm_head", "w")


def chunked_cross_entropy(hidden, w_head, labels, chunk: int = CE_CHUNK):
    """Per-token CE without materializing [B,S,V] logits: scan over sequence
    chunks with rematerialization. Returns (sum_loss, token_count)."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    hs = hidden.reshape(b, nc, c, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, xs):
        h, y = xs
        logits = jnp.einsum("bcd,dv->bcv", h, w_head,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total, b * s


def loss_fn(cfg, params_pair, batch, sel=None, remat: bool = True,
            aux_weight: float = 0.01, z_weight: float = 1e-3):
    hidden, aux = forward(cfg, params_pair, batch, sel=sel, remat=remat)
    w_head = lm_head_weight(cfg, params_pair)
    total, count = chunked_cross_entropy(hidden, w_head, batch["labels"])
    ce = total / count
    loss = ce + aux_weight * aux[0] + z_weight * aux[1]
    return loss, {"ce": ce, "load_balance": aux[0], "router_z": aux[1]}
