"""Arch registry: config -> model functions, plus analytic parameter counts
(used by roofline MODEL_FLOPS and the memory-budget solver)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models import transformer as T


def init_params(cfg: ModelConfig, key):
    return T.init_params(cfg, key)


def abstract_params(cfg: ModelConfig):
    """Shape/dtype tree without allocating (for dry-run and planning)."""
    return jax.eval_shape(lambda k: T.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via abstract init. active_only: count only
    top-k routed experts (for MoE MODEL_FLOPS = 6·N_active·D)."""
    tree = abstract_params(cfg)
    total = sum(x.size for x in jax.tree.leaves(tree))
    if not active_only or cfg.moe is None:
        return total
    # subtract inactive routed-expert params
    seg = tree["segments"]
    inactive = 0
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    frac_inactive = (e - k) / e

    def walk(node):
        nonlocal inactive
        if isinstance(node, dict):
            for name, sub in node.items():
                if name in ("w_gate", "w_up", "w_down") and hasattr(sub, "ndim") \
                        and sub.ndim == 4:  # [L, E, in, out]
                    inactive += int(sub.size * frac_inactive)
                elif isinstance(sub, dict):
                    walk(sub)
    walk(seg)
    return total - inactive


def flops_per_token(cfg: ModelConfig, train: bool = True) -> float:
    """MODEL_FLOPS per token: 6·N (train) or 2·N (inference) on active
    params, plus attention score FLOPs are excluded (reported separately)."""
    n = param_count(cfg, active_only=True)
    return (6.0 if train else 2.0) * n
