"""Token-choice top-k MoE with expert parallelism.

Dispatch strategy ("local dispatch EP"): tokens stay on their data shard;
expert weights are sharded over the `model` mesh axis (EP). Each device
builds capacity-bounded buffers for *its own* experts from its local tokens
(sort-based, no one-hot dispatch tensors), runs its experts' FFNs, combines
locally, and a single psum over the model axis sums expert partial outputs.
Collectives per layer: one [T_loc, d] psum (forward) — no [E, C, d]
all-to-all / all-gather traffic.

Outside a mesh (CPU unit tests) the same code runs with E_local = E and the
psum skipped.

Serve-time tensor parallelism (inside the paged-decode shard_map) shards a
DIFFERENT axis: every expert is resident on every shard, but the expert
FFN hidden dim d_ff is split column-/row-parallel (like `apply_mlp`) — the
router and the sort-based dispatch run replicated, each shard computes its
d_ff slice of every routed token, and one psum over the model axis
reassembles the combined output. Decode batches are tiny, so sharding the
per-token FLOPs beats sharding the expert set.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.sparse_update import smm
from repro.models.common import dense_init
from repro.sharding import current_mapped_axis, current_rules, psum_mapped
from repro.models import layers as L


def init_moe(key, cfg, dtype):
    moe = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), in_axis=1, dtype=dtype),
    }
    if moe.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], cfg, dtype,
                                 d_ff=moe.num_shared_experts * ff)
    return p


def _expert_ffn(p_slice, cfg, buf, sel):
    """buf: [E_loc, C, d] -> [E_loc, C, d] (swiglu assumed for MoE archs)."""
    h = jax.nn.silu(smm(buf, p_slice["w_gate"], sel, "w_gate"))
    h = h * smm(buf, p_slice["w_up"], sel, "w_up")
    return smm(h, p_slice["w_down"], sel, "w_down")


def _dispatch_combine(cfg, x_flat, ids, weights, wp, sel, axis: Optional[str],
                      e_local: int, capacity: int):
    """Per-device MoE body. x_flat [T,d]; ids/weights [T,k]; wp: expert
    weights already sliced to this device's experts [E_loc, ...]."""
    t, d = x_flat.shape
    k = ids.shape[1]
    e = cfg.moe.num_experts
    m_idx = jax.lax.axis_index(axis) if axis is not None else 0

    flat_e = ids.reshape(-1)                       # [T*k]
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)                    # stable
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]           # position within expert
    valid = pos < capacity
    e_own = se - m_idx * e_local                   # local expert index
    own = (e_own >= 0) & (e_own < e_local) & valid
    dest = jnp.where(own, e_own * capacity + pos, e_local * capacity)

    buf = jnp.zeros((e_local * capacity + 1, d), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[st], mode="drop")
    h = _expert_ffn(wp, cfg, buf[:-1].reshape(e_local, capacity, d), sel)

    gathered = jnp.take(h.reshape(e_local * capacity, d), dest, axis=0,
                        mode="fill", fill_value=0.0)
    contrib = gathered * jnp.where(own, sw, 0.0)[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), contrib.dtype).at[st].add(contrib)
    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y.astype(x_flat.dtype)


def apply_moe(p, cfg, x, sel=None):
    """x: [B, S, d] -> (y [B, S, d], aux_losses dict)."""
    moe = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    e, k = moe.num_experts, moe.top_k

    logits = (x_flat.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # aux losses (switch-style load balance + z-loss)
    frac_tokens = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = probs.mean(0)
    aux = {
        "load_balance": e * jnp.sum(frac_tokens * frac_probs),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    rules = current_rules()
    axis = rules.model_axis if rules is not None and rules.mesh is not None else None
    if axis is not None:
        mesh = rules.mesh
        n_model = mesh.shape[axis]
        t_loc = t // max(1, _batch_shards(rules))
        capacity = _capacity(t_loc, k, moe.capacity_factor, e)
        e_local = e // n_model
        names = ("w_gate", "w_up", "w_down")
        batch_spec = P(rules.rules.get("batch"), None)
        in_specs = [batch_spec, batch_spec, batch_spec] + \
            [P(axis, None, None)] * 3
        args = [x_flat, ids, weights] + [p[n] for n in names]
        # compact path: the wsel leaves must cross shard_map as explicit
        # arguments (sharded over experts like the weights) so their
        # cotangents flow back out; closure capture would drop them
        wsel = sel[2] if sel is not None and len(sel) > 2 else None
        if wsel is not None:
            in_specs += [P(axis, None, None, None, None)] * 3
            args += [wsel[n] for n in names]

            def body(xf, i, w, wg, wu, wd, wsg, wsu, wsd):
                sub = (sel[0], sel[1],
                       {"w_gate": wsg, "w_up": wsu, "w_down": wsd})
                return _dispatch_combine(
                    cfg, xf, i, w, {"w_gate": wg, "w_up": wu, "w_down": wd},
                    sub, axis, e_local, capacity)
        else:
            def body(xf, i, w, wg, wu, wd):
                return _dispatch_combine(
                    cfg, xf, i, w, {"w_gate": wg, "w_up": wu, "w_down": wd},
                    sel, axis, e_local, capacity)
        y_flat = shard_map(
            body, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=P(rules.rules.get("batch"), None),
            check_vma=False,
        )(*args)
    else:
        capacity = _capacity(t, k, moe.capacity_factor, e)
        y_flat = _dispatch_combine(cfg, x_flat, ids, weights,
                                   {kk: p[kk] for kk in ("w_gate", "w_up", "w_down")},
                                   sel, None, e, capacity)
        # serve mesh (inside the paged-decode shard_map): router + dispatch
        # replicated, every expert resident, but the expert hidden dim
        # arrived d_ff-sharded — each shard's combine holds the partial
        # w_down contraction of its d_ff slice, one psum reassembles it
        if current_mapped_axis() is not None and \
                p["w_gate"].shape[-1] != cfg.d_ff:
            y_flat = psum_mapped(y_flat)

    y = y_flat.reshape(b, s, d)
    if "shared" in p:
        y = y + L.apply_mlp(p["shared"], cfg, x, sel=_shared_sel(sel),
                            d_ff=moe.num_shared_experts * cfg.d_ff)
    return y, aux


def _shared_sel(sel):
    if sel is None:
        return None
    idx, spec = sel[0], sel[1]
    if idx is None or "shared" not in idx or "shared" not in spec:
        return None
    return tuple(comp["shared"] for comp in sel)


def _capacity(t_loc: int, k: int, cf: float, e: int) -> int:
    c = int(t_loc * k * cf / e) + 1
    return max(8, min(c, t_loc * k))


def _batch_shards(rules) -> int:
    n = 1
    for a in rules.batch_axes:
        n *= rules.mesh.shape[a]
    return n
