"""Core layers: norms, RoPE / M-RoPE, GQA attention (dense / diagonal-block
flash / sliding-window / cached decode), MLP variants.

All functions are pure; params are plain dicts of jnp arrays. Matmuls that
participate in dynamic gradient sparse update go through
``repro.core.sparse_update.smm`` (sparse-matmul) so the backward pass skips
unselected output-channel blocks.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_update import smm
from repro.models.common import col_matmul, dense_init, row_matmul
from repro import sharding as SH
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_group_norm(key, c: int, groups: int, dtype):
    del groups  # static — passed to apply_group_norm
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def apply_group_norm(p, x, groups: int, eps: float = 1e-5):
    """x: [B, H, W, C] (NHWC)."""
    b, h, w, c = x.shape
    g = groups
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(b, h, w, c)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S] int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., :, None, :]                    # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Pair-counts for (temporal, height, width); qwen2-vl uses 16/24/24 of 64
    pairs for head_dim=128 — i.e. fractions (1/4, 3/8, 3/8)."""
    pairs = head_dim // 2
    t = pairs // 4
    h = (pairs - t) // 2
    w = pairs - t - h
    return t, h, w


def apply_mrope(x, positions_thw, theta: float):
    """M-RoPE (qwen2-vl): positions_thw [3, ..., S]; frequency bands are
    partitioned between the three position components."""
    d = x.shape[-1]
    pairs = d // 2
    t, h, w = mrope_sections(d)
    freqs = rope_frequencies(d, theta)                       # [pairs]
    section = jnp.concatenate([
        jnp.zeros((t,), jnp.int32), jnp.ones((h,), jnp.int32),
        jnp.full((w,), 2, jnp.int32)])
    # pick position component per frequency band
    pos = jnp.take(positions_thw, section, axis=0)           # [pairs, ..., S]
    pos = jnp.moveaxis(pos, 0, -1)                           # [..., S, pairs]
    angles = pos.astype(jnp.float32) * freqs                 # [..., S, pairs]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, cfg.num_heads * hd), dtype=dtype),
        "wk": dense_init(kk, (d, cfg.num_kv_heads * hd), dtype=dtype),
        "wv": dense_init(kv, (d, cfg.num_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ko, (cfg.num_heads * hd, d), dtype=dtype),
    }


def _qkv(p, cfg, x, positions, sel=None, delta=None):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    # head counts come from the projection widths, not cfg: inside a
    # shard_map over the model axis each shard holds a head-block of
    # wq/wk/wv (column-parallel), so the local head count is cfg's divided
    # by the shard count; full_out lets a rider delta land on the owning
    # shard only
    q = col_matmul(x, p["wq"], sel, "wq", delta,
                   full_out=cfg.num_heads * hd).reshape(b, s, -1, hd)
    k = col_matmul(x, p["wk"], sel, "wk", delta,
                   full_out=cfg.num_kv_heads * hd).reshape(b, s, -1, hd)
    v = col_matmul(x, p["wv"], sel, "wv", delta,
                   full_out=cfg.num_kv_heads * hd).reshape(b, s, -1, hd)
    if getattr(cfg, "mrope", False):
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _expand_kv(k, hq: int):
    """GQA expansion via gather (sharding-friendly on the head axis):
    [B,S,Hkv,D] -> [B,S,Hq,D]."""
    hkv = k.shape[2]
    if hkv == hq:
        return k
    return jnp.take(k, jnp.arange(hq) // (hq // hkv), axis=2)


def _sdpa_dense(q, k, v, window: int = 0):
    """Materialized causal attention. q:[B,S,Hq,D] k,v:[B,S,Hkv,D]."""
    b, s, hq, dd = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dd)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def _diag_mask(c: int, diag: int, window: int):
    qpos_in = jnp.arange(c)[:, None]
    kpos_in = jnp.arange(c)[None, :]
    delta = qpos_in - kpos_in + diag * c     # distance q-k; >= 0 is causal
    mask = delta >= 0
    if window:
        mask &= delta < window
    return mask


def _flash_fwd_impl(q, k, v, window: int, c: int):
    """Diagonal-block causal flash attention forward (pure jnp, online
    softmax). Only on/below-diagonal blocks are computed (no causal-FLOP
    waste); sliding windows statically truncate the diagonal range.
    Returns (out [b,s,h,d], lse [b,n,c,h])."""
    b, s, hq, dd = q.shape
    n = s // c
    qb = q.reshape(b, n, c, hq, dd)
    kb = k.reshape(b, n, c, hq, dd)
    vb = v.reshape(b, n, c, hq, dd)

    scale = 1.0 / math.sqrt(dd)
    m = jnp.full((b, n, c, hq), -1e30, jnp.float32)    # running max
    l = jnp.zeros((b, n, c, hq), jnp.float32)           # running denom
    o = jnp.zeros((b, n, c, hq, dd), jnp.float32)       # running numer

    max_diag = n if not window else min(n, (window + c - 1) // c + 1)
    for diag in range(max_diag):
        nb = n - diag                        # blocks on this diagonal
        qs = qb[:, diag:, ...]               # [b, nb, c, hq, dd]
        ks = kb[:, :nb, ...]
        vs = vb[:, :nb, ...]
        sc = jnp.einsum("bnqhd,bnkhd->bnqhk", qs, ks,
                        preferred_element_type=jnp.float32) * scale
        mask = _diag_mask(c, diag, window)
        sc = jnp.where(mask[None, None, :, None, :], sc, -1e30)
        blk_m = sc.max(axis=-1)                                  # [b,nb,c,hq]
        m_old = m[:, diag:, ...]
        m_new = jnp.maximum(m_old, blk_m)
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l = l.at[:, diag:, ...].set(l[:, diag:, ...] * corr + p.sum(axis=-1))
        pv = jnp.einsum("bnqhk,bnkhd->bnqhd", p.astype(q.dtype), vs,
                        preferred_element_type=jnp.float32)
        o = o.at[:, diag:, ...].set(o[:, diag:, ...] * corr[..., None] + pv)
        m = m.at[:, diag:, ...].set(m_new)
    out = o / jnp.maximum(l[..., None], 1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out.astype(q.dtype).reshape(b, s, hq, dd), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attn(q, k, v, window: int, c: int):
    return _flash_fwd_impl(q, k, v, window, c)[0]


def _flash_attn_fwd(q, k, v, window, c):
    out, lse = _flash_fwd_impl(q, k, v, window, c)
    return out, (q, k, v, out, lse)


def _flash_attn_bwd(window, c, res, dout):
    """Flash backward: recompute probabilities per diagonal from (q,k,lse)
    — O(S·d) residual memory instead of O(S^2) (the dominant training-memory
    term at 4k+ sequence lengths; see EXPERIMENTS.md §Perf iteration 1)."""
    q, k, v, out, lse = res
    b, s, hq, dd = q.shape
    n = s // c
    scale = 1.0 / math.sqrt(dd)
    qb = q.reshape(b, n, c, hq, dd)
    kb = k.reshape(b, n, c, hq, dd)
    vb = v.reshape(b, n, c, hq, dd)
    dob = dout.reshape(b, n, c, hq, dd)
    ob = out.reshape(b, n, c, hq, dd)
    # delta_i = sum_d dout_i * out_i  (the softmax normalization term)
    delta = jnp.einsum("bnqhd,bnqhd->bnqh", dob.astype(jnp.float32),
                       ob.astype(jnp.float32))

    dq = jnp.zeros((b, n, c, hq, dd), jnp.float32)
    dk = jnp.zeros((b, n, c, hq, dd), jnp.float32)
    dv = jnp.zeros((b, n, c, hq, dd), jnp.float32)
    max_diag = n if not window else min(n, (window + c - 1) // c + 1)
    for diag in range(max_diag):
        nb = n - diag
        qs = qb[:, diag:, ...]
        ks = kb[:, :nb, ...]
        vs = vb[:, :nb, ...]
        dos = dob[:, diag:, ...]
        sc = jnp.einsum("bnqhd,bnkhd->bnqhk", qs, ks,
                        preferred_element_type=jnp.float32) * scale
        mask = _diag_mask(c, diag, window)
        sc = jnp.where(mask[None, None, :, None, :], sc, -1e30)
        p = jnp.exp(sc - lse[:, diag:, :, :, None])          # normalized probs
        dv = dv.at[:, :nb].add(jnp.einsum(
            "bnqhk,bnqhd->bnkhd", p.astype(q.dtype), dos,
            preferred_element_type=jnp.float32))
        dp = jnp.einsum("bnqhd,bnkhd->bnqhk", dos, vs,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, diag:, :, :, None]) * scale
        ds = ds.astype(q.dtype)
        dq = dq.at[:, diag:].add(jnp.einsum(
            "bnqhk,bnkhd->bnqhd", ds, ks, preferred_element_type=jnp.float32))
        dk = dk.at[:, :nb].add(jnp.einsum(
            "bnqhk,bnqhd->bnkhd", ds, qs, preferred_element_type=jnp.float32))
    rs = lambda t: t.reshape(b, s, hq, dd).astype(q.dtype)
    return rs(dq), rs(dk), rs(dv)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def _sdpa_flash(q, k, v, window: int = 0, q_chunk: int = 512,
                kv_chunk: int = 512, naive_vjp: bool = False):
    """Memory-efficient causal attention. naive_vjp=True keeps plain
    autodiff (O(S^2) residuals) — the pre-optimization baseline."""
    b, s, hq, dd = q.shape
    if s <= q_chunk:
        return _sdpa_dense(q, k, v, window)
    assert s % q_chunk == 0 and s % kv_chunk == 0 and q_chunk == kv_chunk, (
        "flash path requires equal, dividing chunks")
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    if naive_vjp:
        return _flash_fwd_impl(q, k, v, window, q_chunk)[0]
    return _flash_attn(q, k, v, window, q_chunk)


def attention(p, cfg, x, positions, *, window: int = 0, sel=None,
              flash_threshold: int = 2048):
    """Full training/prefill attention over a whole sequence."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, sel=sel)
    if s > flash_threshold:
        out = _sdpa_flash(q, k, v, window)
    else:
        out = _sdpa_dense(q, k, v, window)
    out = out.reshape(b, s, -1)
    return smm(out, p["wo"], sel, "wo")


def decode_attention(p, cfg, x, positions, cache, *, window: int = 0):
    """Single-token decode against a KV cache.

    cache: {"k","v": [B, S_cache, Hkv, D], "pos": [B] int32 tokens-so-far}
    `pos` is per batch row so decode slots can sit at different depths
    (continuous batching: a freshly refilled slot decodes position
    `prompt_len` while its neighbours are deep into generation).
    For sliding-window layers the cache is a ring buffer of size `window`.
    """
    b, s, _ = x.shape
    assert s == 1
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(p, cfg, x, positions)
    pos = cache["pos"]                    # [B] position index of the new token
    s_cache = cache["k"].shape[1]
    # ring buffer when windowed (s_cache == window), else direct slot
    slot = pos % s_cache if window > 0 else pos
    rows = jnp.arange(b)
    k_cache = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))

    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    idx = jnp.arange(s_cache)[None, :]
    if window > 0:
        # slot i currently holds position p_at = pos - ((pos - i) mod W);
        # by construction pos - W < p_at <= pos, so only p_at >= 0 matters.
        p_at = pos[:, None] - jnp.mod(pos[:, None] - idx, s_cache)
        valid = p_at >= 0                                      # [B, S_cache]
    else:
        valid = idx <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    out = out.reshape(b, 1, cfg.num_heads * hd)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return smm(out, p["wo"], None, "wo"), new_cache


# ---------------------------------------------------------------------------
# Chunk-capable serving attention (paged + ring)
#
# Both variants process s >= 1 new tokens per call against an existing cache,
# so the serving engine's single step function covers batched decode (s=1
# over all slots) AND chunked prefill (one slot, page-sized chunks) — long
# admissions never stall in-flight decodes behind a monolithic prefill.
# Scores are taken against [cached keys ++ in-chunk keys] with the cache
# read BEFORE the chunk's rows are written, so in-chunk causality never
# depends on write ordering (a ring buffer may overwrite its own chunk).
# ---------------------------------------------------------------------------

def _grouped_scores(q, k_cat, v_cat, mask, cfg=None):
    """q: [B,S,Hq,D]; k_cat/v_cat: [B,L,Hkv,D]; mask: [B,S,L] -> [B,S,Hq*D].

    Hkv comes from k_cat, not cfg: under head-sharded serving each shard
    sees a local head-block (Hq_loc = g * Hkv_loc keeps the GQA grouping
    aligned, so the monolithic reshape below stays correct per shard)."""
    b, s, hq, hd = q.shape
    hkv = k_cat.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bshgd,blhd->bhgsl", qg, k_cat,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgsl,blhd->bshgd", probs.astype(q.dtype), v_cat,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, s, hq * hd)


def _grouped_scores_split(q, k_cat, v_cat, mask, tile: int):
    """Flash-decoding form of `_grouped_scores`: the KV length is split
    into `tile`-sized blocks (one page per block in the serve engine), each
    block contributes an (out, lse)-style partial, and partials merge with
    the online-softmax update from `_flash_fwd_impl` — so long contexts
    reduce over pages instead of materializing one [B,Hq,S,L] score tensor.
    Matches the monolithic softmax to float32 roundoff.
    """
    b, s, hq, hd = q.shape
    hkv = k_cat.shape[2]
    g = hq // hkv
    L = k_cat.shape[1]
    nt = -(-L // tile)
    pad = nt * tile - L
    if pad:
        zkv = jnp.zeros((b, pad) + k_cat.shape[2:], k_cat.dtype)
        k_cat = jnp.concatenate([k_cat, zkv], axis=1)
        v_cat = jnp.concatenate([v_cat, zkv], axis=1)
        mask = jnp.concatenate(
            [mask, jnp.zeros((b, s, pad), mask.dtype)], axis=2)

    qg = q.reshape(b, s, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    # tiles to the front so lax.scan walks pages: [NT, B, tile, ...]
    kt = jnp.moveaxis(k_cat.reshape(b, nt, tile, hkv, hd), 1, 0)
    vt = jnp.moveaxis(v_cat.reshape(b, nt, tile, hkv, hd), 1, 0)
    mt = jnp.moveaxis(mask.reshape(b, s, nt, tile), 2, 0)

    m0 = jnp.full((b, hkv, g, s), -1e30, jnp.float32)       # running max
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)             # running denom
    o0 = jnp.zeros((b, hkv, g, s, hd), jnp.float32)         # running numer

    def merge(carry, blk):
        m, l, o = carry
        k_b, v_b, msk = blk
        sc = jnp.einsum("bshgd,bthd->bhgst", qg, k_b,
                        preferred_element_type=jnp.float32) * scale
        sc = jnp.where(msk[:, None, None, :, :], sc, -1e30)
        blk_m = sc.max(axis=-1)
        m_new = jnp.maximum(m, blk_m)
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgst,bthd->bhgsd", p.astype(q.dtype), v_b,
                        preferred_element_type=jnp.float32)
        o = o * corr[..., None] + pv
        return (m_new, l, o), None

    (m, l, o), _ = jax.lax.scan(merge, (m0, l0, o0), (kt, vt, mt))
    out = o / jnp.maximum(l[..., None], 1e-30)              # [B,Hkv,G,S,D]
    out = jnp.moveaxis(out, 3, 1)                           # [B,S,Hkv,G,D]
    return out.astype(q.dtype).reshape(b, s, hq * hd)


def _serve_positions(cfg, start, s):
    """Token positions for a chunk: [B,S] (or [3,B,S] broadcast for mrope)."""
    pos = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    if getattr(cfg, "mrope", False):
        pos = jnp.broadcast_to(pos, (3,) + pos.shape)
    return pos


def chunk_ring_attention(p, cfg, x, start, active, cache, *, window: int,
                         length=None, delta=None):
    """Sliding-window attention for a chunk of s tokens per batch row.

    cache: {"k","v": [B, W, H, D]} ring buffers (position p at slot p % W).
    `start` [B] = tokens already cached per row; rows with active=False get
    their cache returned unchanged (the caller row-selects, but the write
    here must still be computed — shapes are fixed). `length` [B] = valid
    tokens per row (None = all s): rows are padded to a fixed chunk shape
    so the final partial prefill chunk does not retrace, and the writes of
    padded positions MUST be dropped — a ring slot written at a padded
    position would masquerade as an earlier (mod-W-aliased) position on the
    next read.
    """
    b, s, _ = x.shape
    if length is None:
        length = jnp.full((b,), s, jnp.int32)
    w_cap = cache["k"].shape[1]
    q, k, v = _qkv(p, cfg, x, _serve_positions(cfg, start, s), delta=delta)

    # Under head-sharded serving the ring cache arrives replicated (it is
    # per-slot engine state, not pool state): slice this shard's head block,
    # attend and write locally, and gather the heads back before returning
    # so the state leaves the shard_map replicated again.
    ax = SH.current_mapped_axis()
    hkv_loc = k.shape[2]
    local_heads = ax is not None and hkv_loc != cache["k"].shape[2]
    ring_k, ring_v = cache["k"], cache["v"]
    if local_heads:
        off = jax.lax.axis_index(ax) * hkv_loc
        ring_k = jax.lax.dynamic_slice_in_dim(ring_k, off, hkv_loc, axis=2)
        ring_v = jax.lax.dynamic_slice_in_dim(ring_v, off, hkv_loc, axis=2)

    j = jnp.arange(s)
    qpos = start[:, None] + j[None, :]                       # [B, S]
    # ring part: slot i holds the latest position == i (mod W) that is
    # <= start-1 (pre-chunk content); negative p_at -> never written
    idx = jnp.arange(w_cap)[None, :]
    last = start[:, None] - 1
    p_at = last - jnp.mod(last - idx, w_cap)                 # [B, W]
    ring_mask = (p_at[:, None, :] >= 0) & \
        (qpos[:, :, None] - p_at[:, None, :] < window)       # [B, S, W]
    # in-chunk part: causal within the chunk, window-limited
    chunk_mask = (j[None, :] <= j[:, None]) & (j[:, None] - j[None, :] < window)
    chunk_mask = jnp.broadcast_to(chunk_mask[None], (b, s, s))

    k_cat = jnp.concatenate([ring_k.astype(k.dtype), k], axis=1)
    v_cat = jnp.concatenate([ring_v.astype(v.dtype), v], axis=1)
    mask = jnp.concatenate([ring_mask, chunk_mask], axis=2)
    out = _grouped_scores(q, k_cat, v_cat, mask, cfg)

    # write the chunk into the ring: position p -> slot p % W; among the
    # valid (non-padded) rows only the last W survive, so earlier rows are
    # dropped via an out-of-bounds slot (duplicate in-bounds scatters have
    # no defined order); padded rows (j >= length) never write
    keep = (j[None, :] < length[:, None]) & \
        (j[None, :] >= length[:, None] - w_cap) & active[:, None]
    slot = jnp.where(keep, jnp.mod(qpos, w_cap), w_cap)      # [B, S]
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s))
    k_cache = ring_k.at[rows, slot].set(
        k.astype(ring_k.dtype), mode="drop")
    v_cache = ring_v.at[rows, slot].set(
        v.astype(ring_v.dtype), mode="drop")
    if local_heads:
        k_cache = SH.all_gather_mapped(k_cache, axis=2)
        v_cache = SH.all_gather_mapped(v_cache, axis=2)
    y = row_matmul(out, p["wo"], None, "wo", delta,
                   full_in=cfg.num_heads * cfg.resolved_head_dim)
    return y, {"k": k_cache, "v": v_cache}


def chunk_paged_attention(p, cfg, x, start, active, pool, page_table, *,
                          page_size: int, length=None, delta=None,
                          flash_decode: bool = False):
    """Full (window-free) attention for a chunk of s tokens per batch row,
    reading and writing K/V through per-row page tables.

    pool: {"k","v": [R, H, D]} physical token rows shared by ALL batch rows
    (R = num_pages * page_size); page_table: [B, MP] int32 physical page per
    logical page, -1 where unallocated. Writes of inactive rows, rows
    whose page is unallocated, and padded rows (`length` [B] = valid tokens
    per row, None = all s) are dropped via out-of-bounds indices — padded
    garbage must never land in a page a later request could share.
    """
    b, s, _ = x.shape
    if length is None:
        length = jnp.full((b,), s, jnp.int32)
    ps = page_size
    r_rows = pool["k"].shape[0]
    mp = page_table.shape[1]
    q, k, v = _qkv(p, cfg, x, _serve_positions(cfg, start, s), delta=delta)

    # gather the cached prefix in logical order: [B, MP*ps] physical rows
    phys = jnp.clip(page_table, 0)[:, :, None] * ps + \
        jnp.arange(ps)[None, None, :]
    phys = phys.reshape(b, mp * ps)
    k_cache = jnp.take(pool["k"], phys, axis=0)              # [B, L, H, D]
    v_cache = jnp.take(pool["v"], phys, axis=0)

    l_idx = jnp.arange(mp * ps)[None, :]                     # logical index
    alloc = jnp.take_along_axis(page_table, l_idx // ps, axis=1) >= 0
    cache_mask = (l_idx < start[:, None]) & alloc            # [B, L]
    cache_mask = jnp.broadcast_to(cache_mask[:, None, :], (b, s, mp * ps))
    j = jnp.arange(s)
    chunk_mask = jnp.broadcast_to((j[None, :] <= j[:, None])[None], (b, s, s))

    k_cat = jnp.concatenate([k_cache.astype(k.dtype), k], axis=1)
    v_cat = jnp.concatenate([v_cache.astype(v.dtype), v], axis=1)
    mask = jnp.concatenate([cache_mask, chunk_mask], axis=2)
    if flash_decode:
        out = _grouped_scores_split(q, k_cat, v_cat, mask, tile=ps)
    else:
        out = _grouped_scores(q, k_cat, v_cat, mask, cfg)

    # write the chunk rows: logical position -> page_table page; unallocated
    # pages / inactive rows land out of bounds and are dropped
    wpos = start[:, None] + j[None, :]                       # [B, S]
    pid = jnp.take_along_axis(page_table, wpos // ps, axis=1)
    dest = jnp.where((pid >= 0) & active[:, None] &
                     (j[None, :] < length[:, None]),
                     pid * ps + wpos % ps, r_rows).reshape(-1)
    k_pool = pool["k"].at[dest].set(
        k.reshape(b * s, *k.shape[2:]).astype(pool["k"].dtype), mode="drop")
    v_pool = pool["v"].at[dest].set(
        v.reshape(b * s, *v.shape[2:]).astype(pool["v"].dtype), mode="drop")
    # under head-sharded serving each shard's wo rows cover only its local
    # heads: row-parallel matmul, one psum reassembles the output (identity
    # outside shard_map); a rider delta is applied on the local d_in slice
    # before the reduction
    y = row_matmul(out, p["wo"], None, "wo", delta,
                   full_in=cfg.num_heads * cfg.resolved_head_dim)
    return y, {"k": k_pool, "v": v_pool}


def init_kv_cache(cfg, batch: int, seq_len: int, *, window: int = 0, dtype=None):
    hd = cfg.resolved_head_dim
    size = min(window, seq_len) if window > 0 else seq_len
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def ring_snapshot_leaves(cfg, window: int, max_len: int, dtype=None):
    """Per-row (shape, dtype) spec of a ring layer's serve-cache state — the
    snapshot unit a prefix cache stores at a page boundary. The serve ring
    leaf carries no per-row `pos` (position is the engine's slot.pos), so
    the snapshot is the k/v buffers only."""
    hd = cfg.resolved_head_dim
    size = min(window, max_len)
    dt = dtype or jnp.dtype(cfg.dtype)
    return {"k": ((size, cfg.num_kv_heads, hd), dt),
            "v": ((size, cfg.num_kv_heads, hd), dt)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    kind = cfg.mlp_kind
    if kind == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w_gate": dense_init(k1, (d, ff), dtype=dtype),
                "w_up": dense_init(k2, (d, ff), dtype=dtype),
                "w_down": dense_init(k3, (ff, d), dtype=dtype)}
    if kind in ("gelu", "sq_relu"):
        k1, k2 = jax.random.split(key, 2)
        return {"w_up": dense_init(k1, (d, ff), dtype=dtype),
                "w_down": dense_init(k2, (ff, d), dtype=dtype)}
    raise ValueError(kind)


def apply_mlp(p, cfg, x, sel=None, delta=None, d_ff: Optional[int] = None):
    """gate/up are column-parallel (ff sharded on the model axis at serve
    time), down is row-parallel (one psum). `d_ff` is the FULL hidden width
    when it differs from cfg.d_ff (dense-first MoE segment, shared-expert
    MLP) — the col/row primitives compare it against the local weight shape
    to tell sharded from replicated-fallback leaves."""
    ff = d_ff or cfg.d_ff
    kind = cfg.mlp_kind
    if kind == "swiglu":
        h = jax.nn.silu(
            col_matmul(x, p["w_gate"], sel, "w_gate", delta, full_out=ff)) * \
            col_matmul(x, p["w_up"], sel, "w_up", delta, full_out=ff)
    elif kind == "gelu":
        h = jax.nn.gelu(
            col_matmul(x, p["w_up"], sel, "w_up", delta, full_out=ff))
    elif kind == "sq_relu":
        h = col_matmul(x, p["w_up"], sel, "w_up", delta, full_out=ff)
        h = jax.nn.relu(h)
        h = h * h
    else:
        raise ValueError(kind)
    h = constrain(h, "batch", "seq", "ff")
    return row_matmul(h, p["w_down"], sel, "w_down", delta, full_in=ff)
