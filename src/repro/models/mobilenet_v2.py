"""MobileNetV2 + GroupNorm — the paper's own experiment substrate.

BN is replaced by GN (paper §IV-A: batch-independent statistics for bs=1
edge training); GN layers are FROZEN during transfer (paper §IV-C).

Sparse update: 1x1 (pointwise) convs participate in channel-block selection
via `sconv` (conv analogue of core.sparse_update.smm — dW computed only for
selected output-channel blocks). Depthwise 3x3 convs are layer-selected but
not channel-masked (<2% of conv params; recorded in DESIGN §8).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.layers import apply_group_norm, init_group_norm


# ---------------------------------------------------------------------------
# sparse conv (paper's gradient skip for convolutions)
# ---------------------------------------------------------------------------

def _conv(x, w, stride: int, groups: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _sconv(x, w, idx, stride: int, spec):
    return _conv(x, w, stride)


def _sconv_fwd(x, w, idx, stride, spec):
    return _conv(x, w, stride), (x, w, idx)


def _sconv_bwd(stride, spec, res, dy):
    x, w, idx = res
    _, dx_fn = jax.vjp(lambda x_: _conv(x_, w, stride), x)
    (dx,) = dx_fn(dy)
    block, n_sel, n_blocks = spec
    # gather selected output-channel blocks of dy (channels last)
    dyb = dy.reshape(dy.shape[:-1] + (n_blocks, block))
    idxb = idx.reshape(idx.shape[-1])  # [n_sel] (single shard on edge device)
    dy_sel = jnp.take(dyb, idxb, axis=-2).reshape(dy.shape[:-1] + (n_sel * block,))
    w_sel_shape = w.shape[:-1] + (n_sel * block,)
    _, dw_fn = jax.vjp(
        lambda w_: _conv(x, w_, stride), jnp.zeros(w_sel_shape, w.dtype))
    (dw_sel,) = dw_fn(dy_sel)
    dw_selb = dw_sel.reshape(w.shape[:-1] + (n_sel, block))
    zeros = jnp.zeros(w.shape[:-1] + (n_blocks, block), w.dtype)
    dw = zeros.at[..., idxb, :].set(dw_selb).reshape(w.shape)
    return dx, dw, None


_sconv.defvjp(_sconv_fwd, _sconv_bwd)


def sconv(x, w, sel, name: str, stride: int = 1, groups: int = 1):
    if sel is not None and groups == 1:
        # (idx, spec) or (idx, spec, wsel): convs have no compact path yet,
        # so any wsel component is ignored (dense-scatter VJP)
        idx_dict, spec_dict = sel[0], sel[1]
        if idx_dict is not None and name in idx_dict:
            sp = spec_dict[name]
            return _sconv(x, w, idx_dict[name], stride,
                          (sp.block, sp.n_sel, sp.n_blocks))
    return _conv(x, w, stride, groups)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _make_divisible(v, divisor=8):
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def conv_layer_names(cfg) -> list[str]:
    """Ordered conv weight names, forward order (for last-K selection)."""
    names = ["stem/w"]
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        for i in range(n):
            base = f"b{idx}"
            if t != 1:
                names.append(f"{base}/expand/w")
            names.append(f"{base}/dw/w")
            names.append(f"{base}/project/w")
            idx += 1
    names.append("head/w")
    return names


def init_params(cfg, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    wm = cfg.width_mult
    params: dict[str, Any] = {}
    keys = iter(jax.random.split(key, 200))

    def conv_init(k, shape):
        # Every conv here feeds a GroupNorm, so the forward pass is invariant
        # to the conv weight's scale — but SGD's effective step on a scale-
        # invariant weight goes as lr/|w|^2, so the He gain of 2.0 (sized for
        # un-normalized ReLU nets) quarters the usable learning rate. Gain 0.5
        # keeps the same shape-conditioning at half the norm.
        fan_in = shape[0] * shape[1] * shape[2]
        return (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
                * (0.5 / fan_in) ** 0.5).astype(dtype)

    c_in = cfg.in_channels
    c_stem = _make_divisible(cfg.stem_channels * wm)
    params["stem"] = {"w": conv_init(next(keys), (3, 3, c_in, c_stem)),
                      "gn": init_group_norm(next(keys), c_stem, cfg.gn_groups, dtype)}
    c_prev = c_stem
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        c_out = _make_divisible(c * wm)
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = c_prev * t
            blk = {}
            if t != 1:
                blk["expand"] = {"w": conv_init(next(keys), (1, 1, c_prev, hidden)),
                                 "gn": init_group_norm(next(keys), hidden,
                                                       cfg.gn_groups, dtype)}
            blk["dw"] = {"w": conv_init(next(keys), (3, 3, 1, hidden)),
                         "gn": init_group_norm(next(keys), hidden,
                                               cfg.gn_groups, dtype)}
            blk["project"] = {"w": conv_init(next(keys), (1, 1, hidden, c_out)),
                              "gn": init_group_norm(next(keys), c_out,
                                                    cfg.gn_groups, dtype)}
            params[f"b{idx}"] = blk
            c_prev = c_out
            idx += 1
    c_head = _make_divisible(cfg.head_channels * max(1.0, wm))
    params["head"] = {"w": conv_init(next(keys), (1, 1, c_prev, c_head)),
                      "gn": init_group_norm(next(keys), c_head, cfg.gn_groups, dtype)}
    params["classifier"] = {"w": dense_init(next(keys), (c_head, cfg.num_classes),
                                            dtype=dtype),
                            "b": jnp.zeros((cfg.num_classes,), dtype)}
    return params


def _pick(frozen, trainable, *path):
    for tree in (trainable, frozen):
        if tree is None:
            continue
        node = tree
        ok = True
        for k in path:
            if not isinstance(node, dict) or k not in node or node[k] is None:
                ok = False
                break
            node = node[k]
        if ok:
            return node
    raise KeyError(path)


def forward(cfg, params_pair, images, sel=None, act_prune=None):
    """images: [B, H, W, 3] -> logits [B, num_classes].

    act_prune: optional callable applied to post-ReLU activations (block
    activation pruning, core.act_prune)."""
    frozen, trainable = params_pair
    relu6 = lambda v: jnp.clip(v, 0.0, 6.0)
    ap = act_prune if act_prune is not None else (lambda v: v)

    def cbr(x, p, name, stride=1, groups=1):
        x = sconv(x, p["w"], sel, name, stride=stride, groups=groups)
        x = apply_group_norm(p["gn"], x, cfg.gn_groups)
        return ap(relu6(x))

    x = images
    p = _pick(frozen, trainable, "stem")
    x = cbr(x, p, "stem/w", stride=2)
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        for i in range(n):
            base = f"b{idx}"
            blk = _pick(frozen, trainable, base)
            inp = x
            if "expand" in blk:
                x = cbr(x, blk["expand"], f"{base}/expand/w")
            stride = s if i == 0 else 1
            hidden = x.shape[-1]
            x = cbr(x, blk["dw"], f"{base}/dw/w", stride=stride, groups=hidden)
            x = sconv(x, blk["project"]["w"], sel, f"{base}/project/w")
            x = apply_group_norm(blk["project"]["gn"], x, cfg.gn_groups)
            if stride == 1 and inp.shape == x.shape:
                x = x + inp
            idx += 1
    p = _pick(frozen, trainable, "head")
    x = cbr(x, p, "head/w")
    x = x.mean(axis=(1, 2))
    cl = _pick(frozen, trainable, "classifier")
    return x @ cl["w"] + cl["b"]


def loss_fn(cfg, params_pair, batch, sel=None, act_prune=None):
    logits = forward(cfg, params_pair, batch["images"], sel=sel,
                     act_prune=act_prune).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}
