"""Logical-axis partition specs for every param leaf, derived from the
abstract param tree by leaf-name rules (megatron-style TP + EP).

Used by the dry-run (NamedSharding for pjit in_shardings) and by the
selection planner (a weight's out-dim TP degree = its selection shard count).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.models.registry import abstract_params

# leaf name -> logical axes of the *trailing* dims (leading "layers"/"expert"
# axes are added automatically from ndim).
_RULES_2D = {
    # attention (column-parallel qkv, row-parallel o)
    "wq": ("model_d", "heads"),
    "wk": ("model_d", "kv_heads"),
    "wv": ("model_d", "kv_heads"),
    "wo": ("heads", "model_d"),
    # mlp (column-parallel up/gate, row-parallel down)
    "w_gate": ("model_d", "ff"),
    "w_up": ("model_d", "ff"),
    "w_down": ("ff", "model_d"),
    # mamba
    "in_proj": ("model_d", "d_inner"),
    "out_proj": ("d_inner", "model_d"),
    "x_proj": ("d_inner", None),
    "dt_proj": (None, "d_inner"),
    "A_log": ("d_inner", None),
    # moe router (replicated)
    "router": ("model_d", None),
    # rwkv decay lora (replicated: heads not divisible by wide TP)
    "wA": (None, None),
    "wB": (None, None),
    "u": (None, None),
    "mu": (None, None),
}
_RULES_1D = {
    "conv_b": ("d_inner",),
    "dt_bias": ("d_inner",),
    "D": ("d_inner",),
    "w0": (None,),
}
# rwkv time-mix square mats are replicated (40 heads ∤ 16-way TP); its
# channel-mix uses the regular mlp-style rules below.
_RWKV_TIME_REPLICATED = {"wr", "wk", "wv", "wg", "wo"}
_RWKV_CHAN = {"wk": ("model_d", "ff"), "wv": ("ff", "model_d"),
              "wr": (None, None)}


def _leaf_spec(path: tuple[str, ...], leaf) -> tuple[Optional[str], ...]:
    name = path[-1]
    ndim = leaf.ndim
    inside = [p for p in path[:-1]]

    if name == "tok":                                   # embed [V, d]
        return ("vocab", "model_d")
    if path[-2:] == ("lm_head", "w") or (len(path) >= 2 and path[-2] == "lm_head"):
        return ("model_d", "vocab")
    if name in ("scale", "bias"):                       # norms
        return ("layers",) * (ndim - 1) + (None,)
    if name == "conv_w":                                # [*, K, d_inner]
        return ("layers",) * (ndim - 2) + (None, "d_inner")

    in_time_mix = "time" in inside
    in_chan_mix = "chan" in inside
    if in_time_mix and name in _RWKV_TIME_REPLICATED:
        return ("layers",) * (ndim - 2) + (None, None)
    if in_chan_mix and name in _RWKV_CHAN:
        return ("layers",) * (ndim - 2) + _RWKV_CHAN[name]

    if name in _RULES_1D:
        return ("layers",) * (ndim - 1) + _RULES_1D[name]
    if name in _RULES_2D:
        base = _RULES_2D[name]
        lead = ndim - 2
        # moe expert stacks: [layers, E, in, out] -> expert axis sharded
        if lead >= 1 and name in ("w_gate", "w_up", "w_down") \
                and "moe" in inside and "shared" not in inside:
            lead_axes = ("layers",) * (lead - 1) + ("expert",)
            # expert-sharded weights are NOT TP-sharded on ff
            inner = tuple(None if a == "ff" else a for a in base)
            return lead_axes + inner
        return ("layers",) * lead + base
    if name in ("w", "b"):                              # CNN leaves (no TP)
        return (None,) * ndim
    # fallback: replicated
    return ("layers",) * max(0, ndim - 1) + (None,) * min(1, ndim)


def param_logical_specs(cfg):
    """Tree of logical-axis tuples mirroring init_params(cfg)."""
    tree = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def path_names(path):
        out = []
        for k in path:
            if hasattr(k, "key"):
                out.append(str(k.key))
        return tuple(out)

    specs = {}
    for path, leaf in flat:
        specs[path_names(path)] = _leaf_spec(path_names(path), leaf)
    # rebuild nested structure
    return _unflatten(specs)


def _unflatten(flat: dict[tuple[str, ...], tuple]) -> dict:
    root: dict = {}
    for path, val in flat.items():
        node = root
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = val
    return root
