"""Serving launcher: thin CLI over the paged continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16 \
        --page-size 16 --shared-prefix-len 16 --stream

Requests are admitted into fixed decode slots backed by a paged KV cache:
prompts chunk-prefill a page at a time (long admissions never stall
in-flight decodes), common prompt prefixes share refcounted pages
copy-on-write, and `--stream` prints tokens as they are sampled. Reported
request/token counts cover COMPLETED requests only — padded slots and
cancelled/timed-out requests are never counted. On CPU this serves the
smoke configs; the same engine lowers to the production mesh for the full
configs (see launch/dryrun.py decode cells).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.runtime.chaos import FaultSchedule
from repro.serve import PersonalizationConfig, ServeEngine
from repro.serve.engine import (make_branching_prefix_requests,
                                make_random_requests,
                                make_shared_prefix_requests)


def build_engine(args, cfg=None):
    cfg = cfg or (get_smoke_config(args.arch) if args.smoke
                  else get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    p13n = None
    if args.users > 0:
        from repro.configs.base import OptimizerConfig, SparseUpdateConfig
        p13n = PersonalizationConfig(
            sparse=SparseUpdateConfig(
                update_ratio=args.personalize_ratio,
                num_update_layers=args.personalize_layers,
                channel_block=8),
            optimizer=OptimizerConfig(kind="sgd",
                                      learning_rate=args.personalize_lr),
            store_capacity=args.delta_capacity,
            train_tokens=args.train_tokens, seed=args.seed)
    chaos = None
    if args.fault_rate > 0.0 or args.kill_after is not None:
        chaos = FaultSchedule(args.chaos_seed, fault_rate=args.fault_rate,
                              slow_s=args.chaos_slow_s,
                              kill_after=args.kill_after)
    rules = None
    if getattr(args, "mesh_model", 1) > 1:
        from repro.launch.mesh import make_serve_mesh
        from repro.sharding import default_rules
        rules = default_rules(make_serve_mesh(args.mesh_model))
    flash_decode = True if getattr(args, "flash_decode", False) else None
    engine = ServeEngine(
        cfg, params, num_slots=args.batch,
        max_len=args.prompt_len + args.gen_len,
        temperature=args.temperature, eos_id=args.eos_id, seed=args.seed,
        page_size=args.page_size, num_pages=args.num_pages,
        prefix_sharing=not args.no_prefix_sharing,
        prefix_mode=args.prefix_mode,
        prefix_persist=args.prefix_persist,
        personalization=p13n,
        chaos=chaos, max_retries=args.max_retries,
        shed_watermark=args.shed_watermark, watchdog_s=args.watchdog_s,
        journal=args.journal, rules=rules, flash_decode=flash_decode)
    return cfg, engine


def build_requests(args, cfg):
    if getattr(args, "branching_prefix", False):
        reqs = make_branching_prefix_requests(
            cfg, args.requests, args.prompt_len, args.gen_len,
            page_size=args.page_size,
            max_prefix_pages=max(1, (args.prompt_len - 1) // args.page_size
                                 - 1),
            seed=args.seed)
    elif args.shared_prefix_len > 0:
        reqs = make_shared_prefix_requests(
            cfg, args.requests, args.shared_prefix_len, args.prompt_len,
            args.gen_len, seed=args.seed)
    else:
        reqs = make_random_requests(cfg, args.requests, args.prompt_len,
                                    args.gen_len, seed=args.seed)
    for r in reqs:
        r.timeout_s = args.timeout_s
        if args.users > 0:
            r.user = r.rid % args.users  # round-robin user routing
        if args.stream:
            r.stream = lambda rid, tok: print(
                f"[stream] rid={rid} token={tok}")
    return reqs


def add_serve_args(ap: argparse.ArgumentParser):
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV-cache page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool capacity (default: batch * max pages "
                         "per request, i.e. contiguous-equivalent)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable cross-request prompt-prefix page sharing")
    ap.add_argument("--prefix-mode", choices=("radix", "chain", "off"),
                    default="radix",
                    help="prefix-reuse structure: radix tree with state "
                         "snapshots + spill (default), legacy chain-hash "
                         "baseline, or off")
    ap.add_argument("--prefix-persist", type=str, default=None,
                    help="directory for the persistent prefix tree: the "
                         "spill tier is saved there after each run and "
                         "restored at engine start (radix mode only)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="> 0: requests share a common prompt prefix of "
                         "this many tokens (system-prompt workload)")
    ap.add_argument("--branching-prefix", action="store_true",
                    help="partially-overlapping (zipf-branching) prefix "
                         "workload instead of uniform-random prompts")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-request wall-clock deadline")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are sampled")
    ap.add_argument("--users", type=int, default=0,
                    help="> 0: route requests round-robin across this many "
                         "user ids and personalize per user (delta store + "
                         "online train waves)")
    ap.add_argument("--personalize-lr", type=float, default=0.05,
                    help="online train-wave sgd learning rate")
    ap.add_argument("--personalize-layers", type=int, default=2,
                    help="trainable layer suffix K for per-user deltas")
    ap.add_argument("--personalize-ratio", type=float, default=0.25,
                    help="channel update ratio for per-user deltas")
    ap.add_argument("--train-tokens", type=int, default=16,
                    help="tokens per online train wave")
    ap.add_argument("--delta-capacity", type=int, default=32,
                    help="max resident per-user deltas (hard LRU bound)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="> 0: deterministic chaos injection — per-draw "
                         "probability of page-alloc / step / stream / slow "
                         "faults (runtime/chaos.py)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="fault-schedule seed: same seed, same faults")
    ap.add_argument("--chaos-slow-s", type=float, default=0.002,
                    help="injected straggler delay per slow fault")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="transient faults absorbed per request before it "
                         "is quarantined")
    ap.add_argument("--shed-watermark", type=float, default=0.0,
                    help="> 0: defer admission when free pages would drop "
                         "below this fraction of the pool")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    help="quarantine a request making no progress for this "
                         "many seconds")
    ap.add_argument("--journal", type=str, default=None,
                    help="request-lifecycle journal file: admitted-but-"
                         "unfinished requests are replayed after a restart")
    ap.add_argument("--kill-after", type=int, default=None,
                    help="inject a hard crash after N completed requests "
                         "(exercises journal replay + prefix persistence)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="> 1: run paged decode through shard_map over a "
                         "(1, N) device mesh — page pools shard over KV "
                         "heads along the model axis, page tables and slot "
                         "state stay replicated")
    ap.add_argument("--flash-decode", action="store_true",
                    help="force the flash-decoding split softmax (page-"
                         "tiled online-softmax partials) even single-device;"
                         " default: on when --mesh-model > 1, off otherwise")
    return ap


def main(argv=None):
    args = add_serve_args(argparse.ArgumentParser()).parse_args(argv)
    cfg, engine = build_engine(args)
    stats = engine.run(build_requests(args, cfg), verbose=not args.stream)
    print(f"[serve] {stats.requests_completed}/{args.requests} requests "
          f"({stats.requests_cancelled} cancelled), "
          f"{stats.tokens_out} tokens in {stats.wall_s:.2f}s "
          f"({stats.tok_per_s:.1f} tok/s incl. compile, "
          f"{stats.refills} slot refills, "
          f"{stats.prefill_chunks} prefill chunks)")
    print(f"[serve] latency p50 {stats.latency_p50_s * 1e3:.1f}ms "
          f"p95 {stats.latency_p95_s * 1e3:.1f}ms")
    print(f"[serve] pages {stats.pages_peak}/{stats.pages_total} peak "
          f"(util {stats.page_util:.2f}), "
          f"prefix hit rate {stats.prefix_hit_rate:.2f}, "
          f"{stats.cow_splits} COW splits")
    if stats.mesh_shards > 1:
        print(f"[serve] mesh: {stats.mesh_shards} model-axis shards, "
              f"{stats.pool_shard_bytes} pool bytes/shard")
    if stats.prefix_mode == "radix":
        print(f"[serve] radix: {stats.radix_nodes} nodes, "
              f"snapshot hit rate {stats.snapshot_hit_rate:.2f} "
              f"({stats.snapshots_stored} stored), "
              f"{stats.spills} spills / {stats.rehydrates} rehydrates, "
              f"{stats.spill_entries} tier entries")
    if args.fault_rate > 0.0 or args.kill_after is not None \
            or args.journal is not None:
        print(f"[serve] chaos: {stats.faults_injected} faults injected "
              f"{dict(stats.faults_by_kind)}, {stats.retries} retries, "
              f"{stats.sheds} sheds, {stats.quarantined} quarantined, "
              f"{stats.watchdog_kills} watchdog kills, "
              f"{stats.stream_errors} stream errors, "
              f"{stats.journal_replays} journal replays, "
              f"{stats.stragglers} straggler waves")
    if args.users > 0:
        print(f"[serve] personalization: {args.users} users, "
              f"{stats.train_waves} train waves "
              f"({stats.train_wave_ms_per_token:.2f}ms/token overhead), "
              f"delta hit rate {stats.delta_hit_rate:.2f}, "
              f"{stats.delta_resident_bytes} delta bytes resident, "
              f"{stats.delta_evictions} evictions")
    return stats


if __name__ == "__main__":
    main()
