"""Serving launcher: batched decode with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16

Continuous-batching-lite: requests are admitted into fixed decode slots;
finished slots are refilled from the queue (slot state = KV cache rows).
On CPU this serves the smoke configs; the same driver lowers to the
production mesh for the full configs (see launch/dryrun.py decode cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import decoding as D
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen_len

    prefill = jax.jit(lambda p, b: D.prefill(cfg, p, b, pad_to=max_len))
    decode = jax.jit(lambda p, b, c: D.decode_step(cfg, p, b, c))

    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]
    done = 0
    t0 = time.perf_counter()
    tokens_out = 0
    while queue:
        batch_prompts = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        while len(batch_prompts) < args.batch:   # pad batch with repeats
            batch_prompts.append(batch_prompts[-1])
        prompts = jnp.asarray(np.stack(batch_prompts))
        logits, cache = prefill(params, {"tokens": prompts})
        toks = jnp.argmax(logits, -1)[:, None]
        outs = [toks]
        for t in range(args.prompt_len, max_len - 1):
            batch = {"tokens": toks,
                     "positions": jnp.full((args.batch, 1), t, jnp.int32)}
            if cfg.mrope:
                batch["positions"] = jnp.broadcast_to(
                    batch["positions"], (3, args.batch, 1))
            if cfg.embed_inputs:
                batch["embeds"] = jax.random.normal(
                    key, (args.batch, 1, cfg.d_model), jnp.dtype(cfg.dtype))
                batch.pop("tokens")
            logits, cache = decode(params, batch, cache)
            toks = jnp.argmax(logits, -1)[:, None]
            outs.append(toks)
        done += len(batch_prompts)
        tokens_out += args.gen_len * args.batch
        print(f"[serve] completed {done}/{args.requests} requests")
    dt = time.perf_counter() - t0
    print(f"[serve] {tokens_out} tokens in {dt:.2f}s "
          f"({tokens_out/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
