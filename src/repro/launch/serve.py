"""Serving launcher: thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16

Requests are admitted into fixed decode slots; a finished slot is
re-prefilled from the queue on the next engine iteration without draining
the batch (slot state = cache rows; see repro/serve/__init__.py for the
slot state machine). Reported request/token counts cover ACTIVE slots only
— padded/free slots are never counted. On CPU this serves the smoke
configs; the same engine lowers to the production mesh for the full
configs (see launch/dryrun.py decode cells).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serve import ServeEngine
from repro.serve.engine import make_random_requests


def build_engine(args, cfg=None):
    cfg = cfg or (get_smoke_config(args.arch) if args.smoke
                  else get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(
        cfg, params, num_slots=args.batch,
        max_len=args.prompt_len + args.gen_len,
        temperature=args.temperature, eos_id=args.eos_id, seed=args.seed)
    return cfg, engine


def add_serve_args(ap: argparse.ArgumentParser):
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = add_serve_args(argparse.ArgumentParser()).parse_args(argv)
    cfg, engine = build_engine(args)
    requests = make_random_requests(cfg, args.requests, args.prompt_len,
                                    args.gen_len, seed=args.seed)
    stats = engine.run(requests, verbose=True)
    print(f"[serve] {stats.requests_completed}/{args.requests} requests, "
          f"{stats.tokens_out} tokens in {stats.wall_s:.2f}s "
          f"({stats.tok_per_s:.1f} tok/s incl. compile, "
          f"{stats.refills} slot refills)")
    print(f"[serve] latency p50 {stats.latency_p50_s * 1e3:.1f}ms "
          f"p95 {stats.latency_p95_s * 1e3:.1f}ms")
    return stats


if __name__ == "__main__":
    main()
