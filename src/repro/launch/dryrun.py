import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks device count at first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun

Outputs one JSON per cell into --out (default experiments/dryrun):
bytes-per-device (arguments/outputs/temps), HLO flops (body-once; see
hlo_analysis), trip-corrected collective bytes by op, and metadata used by
benchmarks/roofline.py.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, SparseUpdateConfig, cell_is_skipped,
                           get_config)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (input_specs, make_decode_cell, make_prefill_cell,
                                make_train_cell, rules_for)
from repro.sharding import use_rules


def _mem_dict(m) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = int(getattr(m, k, 0) or 0)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             mode: str = "sparse", update_ratio: float = 0.2,
             donate: bool = True, mesh_shape: tuple | None = None) -> dict:
    """mesh_shape: optional (data, model) override over the same 256 chips —
    used by the §Perf hillclimb (TP degree tuning); the deliverable table
    always uses the assigned 16x16 / 2x16x16 meshes."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(arch, shape_name)
    mesh_name = ("2x16x16" if multi_pod else "16x16") if mesh_shape is None \
        else "x".join(map(str, mesh_shape))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "mode": mode,
           "kind": shape.kind}
    if skip:
        rec["status"] = "SKIP"
        rec["skip_reason"] = skip
        return rec

    import contextlib
    from repro.core.sparse_update import compact_allreduce
    cgr_ctx = compact_allreduce(True) if mode == "cgr" else contextlib.nullcontext()

    t0 = time.time()
    if mesh_shape is not None:
        from repro.compat import make_mesh
        mesh = make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh, cfg, shape)
    with use_rules(rules), cgr_ctx:
        if shape.kind == "train":
            if mode in ("sparse", "cgr"):
                sparse = SparseUpdateConfig(update_ratio=update_ratio,
                                            num_update_layers=0 or _k(cfg),
                                            channel_block=128)
            else:
                sparse = SparseUpdateConfig(enabled=False)
            step_fn, state_abs, state_sh, batch_abs, batch_sh, plan = \
                make_train_cell(cfg, shape, rules, sparse=sparse)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_abs, batch_abs)
            if mode in ("sparse", "cgr"):
                from repro.core.selection import selected_fraction
                rec["selected_param_fraction"] = selected_fraction(plan, cfg)
                rec["trainable_scan_steps"] = sum(plan.seg_trainable.values())
        elif shape.kind == "decode":
            step_fn, abs_args, shs = make_decode_cell(cfg, shape, rules)
            jitted = jax.jit(step_fn, in_shardings=shs,
                             out_shardings=(None, shs[2]),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(*abs_args)
        else:  # prefill
            step_fn, abs_args, shs = make_prefill_cell(cfg, shape, rules)
            jitted = jax.jit(step_fn, in_shardings=shs, out_shardings=None)
            lowered = jitted.lower(*abs_args)

        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        rec["memory"] = _mem_dict(mem)
        from repro.compat import cost_analysis
        cost = cost_analysis(compiled)
        rec["hlo_flops_body_once"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes_body_once"] = float(cost.get("bytes accessed", 0.0))
        txt = compiled.as_text()
        rec["hlo_instruction_count"] = txt.count(" = ")
        coll = hlo_analysis.collective_bytes(txt)
        rec["collective_bytes_per_device"] = coll["total"]
        rec["collective_wire_bytes_per_device"] = coll["total"]
        rec["collective_bytes_by_op"] = coll["by_op"]
        rec["collective_bytes_naive"] = coll["naive"]
        rec["while_trip_counts"] = sorted(set(
            hlo_analysis.while_trip_counts(txt)))
        rec["num_devices"] = mesh.size
        rec["status"] = "OK"
    return rec


def _k(cfg) -> int:
    from repro.launch.specs import _default_k
    return _default_k(cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--mode", choices=["sparse", "dense", "cgr"],
                    default="sparse")
    ap.add_argument("--update-ratio", type=float, default=0.2)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}__{args.mode}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] {tag}: cached")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=multi, mode=args.mode,
                               update_ratio=args.update_ratio)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "mode": args.mode, "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "OK":
                mb = rec["memory"]["argument_size_in_bytes"] / 2**20
                tmb = rec["memory"]["temp_size_in_bytes"] / 2**20
                extra = (f"args={mb:.0f}MiB temp={tmb:.0f}MiB "
                         f"coll={rec['collective_bytes_per_device']/2**20:.1f}MiB "
                         f"compile={rec['compile_s']:.0f}s")
            elif status == "FAIL":
                extra = rec["error"][:160]
            print(f"[dryrun] {tag}: {status} {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
