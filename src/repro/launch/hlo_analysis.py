"""Post-SPMD HLO analysis: collective-byte accounting with while-loop
trip-count multiplication.

`compiled.cost_analysis()` counts while bodies ONCE (verified empirically),
and our layer stacks are lax.scan loops — so naive summation undercounts
per-layer collectives by the layer count. This module parses
`compiled.as_text()` into computations, resolves each while loop's trip
count from its condition computation (compare-with-constant), and walks the
call graph from ENTRY multiplying byte counts through the loop nest.

Collectives counted: all-reduce, all-gather, reduce-scatter, all-to-all,
collective-permute (+ their async -start forms; -done forms are skipped).
Bytes = sum of operand sizes (the data each device injects into the
interconnect for that op).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    op: str
    result_bytes: int
    operands: list[str]
    body: Optional[str] = None       # while body computation
    cond: Optional[str] = None       # while condition computation
    called: list[str] = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    instrs: dict[str, _Instr] = field(default_factory=dict)
    trip_const: Optional[int] = None   # if this is a while condition


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _split_type_op(rhs: str):
    """Split '<type> <op>(<args>...' into (type_str, op, args). Handles
    tuple types with nested parens/brackets and index comments."""
    rhs = _COMMENT_RE.sub("", rhs).strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: i + 1]
                    rest = rhs[i + 1:].strip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest, re.S)
    if not m:
        return None
    return type_str, m.group(1), m.group(2)
_CALL_ATTR_RE = re.compile(r"(?:condition|body|to_apply|branch_computations)="
                           r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def parse_hlo(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_START_RE.match(stripped)
            name = None
            if m:
                name = m.group(1)
            else:  # e.g. "ENTRY %main.123 (args) -> type {"
                m2 = re.search(r"%([\w.\-]+)", stripped)
                name = m2.group(1) if m2 else f"comp{len(comps)}"
            cur = _Computation(name=name)
            comps[name] = cur
            if stripped.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        mo = _split_type_op(rhs)
        if not mo:
            continue
        type_str, op, args = mo
        instr = _Instr(name=name, op=op, result_bytes=_shape_bytes(type_str),
                       operands=[])
        # operand names: first段 before any attr like ", dimensions="
        arg_main = args.split("), ")[0] if op == "while" else args
        head = re.split(r",\s*(?:channel_id|dimensions|replica_groups|"
                        r"source_target_pairs|to_apply|condition|body|"
                        r"sharding|slice|direction|use_global)", args)[0]
        for om in re.finditer(r"%([\w.\-]+)", head):
            instr.operands.append(om.group(1))
        if op == "while":
            mc = re.search(r"condition=%?([\w.\-]+)", args)
            mb = re.search(r"body=%?([\w.\-]+)", args)
            instr.cond = mc.group(1) if mc else None
            instr.body = mb.group(1) if mb else None
        else:
            for cm in _CALL_ATTR_RE.finditer(args):
                for cname in re.split(r",\s*", cm.group(1)):
                    instr.called.append(cname.lstrip("%"))
        if op == "constant":
            mcst = re.search(r"constant\((-?\d+)\)", rhs)
            if mcst and cur.trip_const is None:
                cur.trip_const = int(mcst.group(1))
        cur.instrs[name] = instr
    return comps


def _trip_count(comps, cond_name: Optional[str]) -> int:
    """Trip count from a scan-style condition (compare iter < constant)."""
    if cond_name is None or cond_name not in comps:
        return 1
    cond = comps[cond_name]
    # scan-style condition: compare(iter, constant(N)) direction=LT
    if cond.trip_const is not None and cond.trip_const > 0:
        return cond.trip_const
    return 1


def collective_bytes(text: str) -> dict:
    """Total per-device collective operand bytes, loop-trip corrected.

    Returns {"total": int, "by_op": {op: int}, "naive": int}."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"total": 0, "by_op": {}, "naive": 0}

    by_op: dict[str, float] = {}
    naive = 0

    def comp_bytes(comp: _Computation, mult: float, seen: tuple) -> float:
        nonlocal naive
        if comp.name in seen:            # recursion guard
            return 0.0
        total = 0.0
        for instr in comp.instrs.values():
            opn = instr.op
            base = None
            for c in _COLLECTIVES:
                if opn == c or opn == c + "-start":
                    base = c
                    break
            if base is not None:
                b = 0
                for o in instr.operands:
                    src = comp.instrs.get(o)
                    if src is not None:
                        b += src.result_bytes
                if b == 0:               # operands unknown -> use result
                    b = instr.result_bytes
                # wire bytes per device (ring algorithms):
                #   all-reduce       ~ 2 x operand  (reduce-scatter + all-gather)
                #   all-gather       ~ result - operand (received bytes)
                #   reduce-scatter   ~ operand - result (sent bytes)
                #   all-to-all       ~ operand     (each device re-sends its shard)
                #   collective-permute ~ operand
                if base == "all-reduce":
                    w = 2 * b
                elif base == "all-gather":
                    w = max(instr.result_bytes - b, b)
                elif base == "reduce-scatter":
                    w = max(b - instr.result_bytes, instr.result_bytes)
                else:
                    w = b
                total += w * mult
                naive += w
                by_op[base] = by_op.get(base, 0.0) + w * mult
            if instr.op == "while" and instr.body in comps:
                trips = _trip_count(comps, instr.cond)
                total += comp_bytes(comps[instr.body], mult * trips,
                                    seen + (comp.name,))
            for cal in instr.called:
                if cal in comps:
                    total += comp_bytes(comps[cal], mult, seen + (comp.name,))
        return total

    total = comp_bytes(entry, 1.0, ())
    return {"total": int(total), "by_op": {k: int(v) for k, v in by_op.items()},
            "naive": int(naive)}


# ---------------------------------------------------------------------------
# zero-init scatter detection (compact-gradient path verification)
# ---------------------------------------------------------------------------
#
# The dense-scatter backward materializes each block-sparse dW by scattering
# the compact blocks into a ZERO buffer (`jnp.put_along_axis(zeros, ...)`),
# which lowers to a stablehlo.scatter whose operand is a broadcast zero
# constant. The compact path's only scatters write updated blocks into LIVE
# tensors (weights / optimizer state). `zero_init_scatters` finds the former
# in jax's StableHLO lowering text (`jax.jit(f).lower(...).as_text()`),
# resolving scatter operands one call level deep (jax outlines
# put_along_axis into private helper funcs whose operand arrives as an
# argument).

_SHLO_FUNC_RE = re.compile(r"func\.func\s+(?:private\s+)?@([\w.\-$]+)\((.*)$")
_SHLO_ZERO_RE = re.compile(
    r"(%[\w#]+)\s*=\s*stablehlo\.constant\s+dense<0(?:\.0*(?:e[+-]?\d+)?)?>")
_SHLO_PROP_RE = re.compile(
    r"(%[\w#]+)\s*=\s*stablehlo\.(?:broadcast_in_dim|reshape|convert|"
    r"transpose)\s+(%[\w#]+)")
_SHLO_SCATTER_RE = re.compile(r'"stablehlo\.scatter"\(([^)]*)\)')
_SHLO_CALL_RE = re.compile(r"=\s*call\s+@([\w.\-$]+)\(([^)]*)\)")
_SHLO_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z][\w]*)>")


def _shlo_tensor(type_str: str):
    m = _SHLO_TENSOR_RE.search(type_str)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(1).split("x") if d) \
        if m.group(1) else ()
    return dims, m.group(2)


def zero_init_scatters(text: str) -> list[dict]:
    """Scatters writing into zero-initialized operands in StableHLO `text`.

    Returns [{"shape": tuple, "dtype": str, "bytes": int, "func": str}] —
    one entry per static occurrence (loop trip counts not applied)."""
    funcs: dict[str, dict] = {}
    cur = None
    pending: list[str] = []            # operands of scatters awaiting types
    for line in text.splitlines():
        s = line.strip()
        fm = _SHLO_FUNC_RE.search(s)
        if fm:
            cur = {"zeros": set(), "scatters": [], "calls": []}
            funcs[fm.group(1)] = cur
            pending = []
            continue
        if cur is None:
            continue
        zm = _SHLO_ZERO_RE.match(s)
        if zm:
            cur["zeros"].add(zm.group(1))
            continue
        pm = _SHLO_PROP_RE.match(s)
        if pm and pm.group(2) in cur["zeros"]:
            cur["zeros"].add(pm.group(1))
            continue
        sm = _SHLO_SCATTER_RE.search(s)
        if sm:
            ops = [o.strip() for o in sm.group(1).split(",")]
            pending.append(ops[0] if ops else "")
            continue
        if pending and s.startswith("})"):
            # region close carries `: (operand_t, idx_t, upd_t) -> result_t`
            out = s.split("->")[-1]
            cur["scatters"].append((pending.pop(), _shlo_tensor(out)))
            continue
        cm = _SHLO_CALL_RE.search(s)
        if cm:
            args = [a.strip() for a in cm.group(2).split(",") if a.strip()]
            cur["calls"].append((cm.group(1), args))

    def rec(shape_dtype, fname):
        if shape_dtype is None:
            return None
        dims, dt = shape_dtype
        n = 1
        for d in dims:
            n *= d
        return {"shape": dims, "dtype": dt,
                "bytes": n * _DTYPE_BYTES.get(dt, 4), "func": fname}

    found = []
    wrappers: dict[str, tuple[int, tuple]] = {}   # func -> (arg idx, shape)
    for name, f in funcs.items():
        for operand, shape_dtype in f["scatters"]:
            if operand in f["zeros"]:
                r = rec(shape_dtype, name)
                if r:
                    found.append(r)
            elif operand.startswith("%arg"):
                try:
                    wrappers[name] = (int(operand[4:]), shape_dtype)
                except ValueError:
                    pass
    for name, f in funcs.items():
        for callee, args in f["calls"]:
            if callee not in wrappers:
                continue
            arg_idx, shape_dtype = wrappers[callee]
            if arg_idx < len(args) and args[arg_idx] in f["zeros"]:
                r = rec(shape_dtype, f"{name}->{callee}")
                if r:
                    found.append(r)
    return found


def weight_gradient_scatters(text: str, specs) -> list[dict]:
    """The subset of `zero_init_scatters(text)` whose shapes match a blocked
    selectable-weight layout — trailing dims (n_shards, n_blocks, block) of
    any SelSpec in `specs` (an iterable). An empty result certifies the
    module contains no full-shape gradient scatter for those weights."""
    sigs = {(sp.n_shards, sp.n_blocks, sp.block) for sp in specs}
    return [r for r in zero_init_scatters(text)
            if len(r["shape"]) >= 3 and tuple(r["shape"][-3:]) in sigs]


# ---------------------------------------------------------------------------
# kernel-launch counting (fused compact-path verification)
# ---------------------------------------------------------------------------
#
# PR 1's compact path issued one pallas_call per TP shard for the sparse dW
# and K x n_shards calls for the block writeback; the fused kernels (PR 3)
# must lower to a CONSTANT number of launch sites per selectable weight
# leaf. On TPU each pallas_call appears in the compiled HLO as a
# tpu_custom_call/Mosaic custom-call; on CPU (interpret mode) the kernel is
# inlined into plain HLO, so the detector also counts `pallas_call`
# equations directly in the jaxpr — backend-independent and what CI runs.

_KERNEL_CALL_RE = re.compile(
    r"custom[-_]call[^\n]*?(?:tpu_custom_call|mosaic|pallas)", re.I)


def _iter_sub_jaxprs(val):
    import jax.core as jc
    if isinstance(val, jc.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jc.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _iter_sub_jaxprs(v)


def kernel_launch_count(obj) -> int:
    """Static Pallas/Mosaic kernel-launch sites in a lowered train step.

    `obj` is either compiled/lowered HLO text (counts tpu_custom_call /
    Mosaic / pallas custom-calls — the TPU path) or a jaxpr / ClosedJaxpr
    (counts `pallas_call` equations recursively through scan/while/pjit
    bodies — the backend-independent path CI uses, since interpret-mode
    lowering inlines kernels into plain HLO). Each site is one compiled
    kernel; a site inside a scan body launches once per trip but the count
    stays O(1) in the trip count — the fused compact path must show a
    constant number of sites per selectable weight leaf, not
    O(K x n_shards)."""
    if isinstance(obj, str):
        return len(_KERNEL_CALL_RE.findall(obj))
    return sum(kernel_launch_breakdown(obj).values())


def kernel_launch_breakdown(obj) -> dict[str, int]:
    """`kernel_launch_count` split by kernel name (jaxpr path only).

    Walks the same recursion as `_count_pallas_eqns` but keys each
    `pallas_call` site by its kernel function name (`eqn.params["name"]`),
    so a test can certify the per-KERNEL launch budget of a lowered train
    step — e.g. the MoE compact step must show exactly one `batched_dw`
    site and one fused-optimizer site per expert-sharded leaf, independent
    of n_experts / K / n_shards."""
    jaxpr = getattr(obj, "jaxpr", obj)      # ClosedJaxpr -> Jaxpr
    out: dict[str, int] = {}

    def site_name(params) -> str:
        # "name_and_src_info" renders as "<fn> at <file>:<line>"; the kernel
        # fns are private `_kernel`s, so key by their defining module stem.
        info = str(params.get("name_and_src_info",
                              params.get("name", "")) or "pallas_call")
        fn = info.split(" at ")[0]
        if " at " in info:
            path = info.split(" at ")[1].rsplit(":", 1)[0]
            stem = path.replace("\\", "/").rsplit("/", 1)[-1]
            stem = stem[:-3] if stem.endswith(".py") else stem
            return f"{stem}.{fn}"
        return fn

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                name = site_name(eqn.params)
                out[name] = out.get(name, 0) + 1
                continue
            for val in eqn.params.values():
                for sub in _iter_sub_jaxprs(val):
                    walk(sub)

    walk(jaxpr)
    return out


# ---------------------------------------------------------------------------
# tensor-parallel replication audit (sharded serving verification)
# ---------------------------------------------------------------------------
#
# The serve mesh promises per-shard FLOPs ~1/N on every weight matmul whose
# sharded dim divides the mesh (models/decoding `paged_param_specs`). That
# property reverts SILENTLY: dropping a leaf's PartitionSpec makes the leaf
# arrive replicated inside the shard_map, the shape-based fallback in model
# code happily runs the full-size matmul on every shard, and tokens stay
# correct — only the FLOP saving is gone. The audit makes that revert loud:
# trace the sharded step, walk every sub-jaxpr (shard_map bodies carry LOCAL
# shapes), and flag any dot_general consuming an operand whose shape equals
# the FULL per-step shape of a leaf the sharding policy says must shard.
# `models/decoding.sharded_param_shapes` builds the forbidden set and the
# allowlist (policy-replicated leaves, e.g. indivisible rwkv head mats) from
# the same divisibility rules the spec builder uses.


def replicated_matmul_leaves(fn, args, forbidden_shapes) -> list[tuple]:
    """Shapes of dot_general operands in `fn(*args)`'s jaxpr that match a
    forbidden (full, unsharded) weight shape — empty means every policy-
    sharded matmul really ran on its local shard. Recurses through
    shard_map / scan / while / pjit / remat bodies."""
    import jax as _jax
    forbidden = {tuple(s) for s in forbidden_shapes}
    hits: list[tuple] = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "dot_general":
                for v in eqn.invars:
                    shape = tuple(getattr(v.aval, "shape", ()))
                    if shape in forbidden:
                        hits.append(shape)
            for val in eqn.params.values():
                for sub in _iter_sub_jaxprs(val):
                    walk(sub)

    walk(_jax.make_jaxpr(fn)(*args).jaxpr)
    return hits


def while_trip_counts(text: str) -> list[int]:
    comps = parse_hlo(text)
    out = []
    for comp in comps.values():
        for instr in comp.instrs.values():
            if instr.op == "while":
                out.append(_trip_count(comps, instr.cond))
    return out
