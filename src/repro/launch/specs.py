"""Dry-run plumbing: abstract inputs (ShapeDtypeStruct), sharding trees, and
step builders for every (arch x shape) cell.

`input_specs()` provides weak-type-correct, shardable stand-ins for every
model input — no device allocation. Modality frontends ([audio]/[vlm]) are
stubs: precomputed frame/patch embeddings per the assignment.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, OptimizerConfig, ShapeConfig,
                                SparseUpdateConfig, TrainConfig)
from repro.models import decoding as D
from repro.models import transformer as T
from repro.models.specs import param_logical_specs
from repro.sharding import AxisRules, default_rules, seq_sharded_rules, use_rules


# ---------------------------------------------------------------------------
# resolution helpers
# ---------------------------------------------------------------------------

def resolve_pspec(shape: tuple, logical: tuple, rules: AxisRules) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    out = []
    for dim, name in zip(shape, logical):
        axes = rules.rules.get(name) if name else None
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= rules.mesh.shape[a]
        out.append(tuple(axes) if dim % size == 0 else None)
    return P(*out)


def tree_shardings(abs_tree, logical_tree, rules: AxisRules):
    """NamedSharding tree for an abstract tree + logical-axes tree."""
    def make(leaf, logical):
        spec = resolve_pspec(leaf.shape, logical, rules)
        return NamedSharding(rules.mesh, spec)
    return jax.tree.map(make, abs_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(i, (str, type(None))) for i in x))


def _replicated(rules: AxisRules):
    return NamedSharding(rules.mesh, P())


def replicate_tree(tree, rules: AxisRules):
    return jax.tree.map(lambda _: _replicated(rules), tree)


# ---------------------------------------------------------------------------
# batch input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract batch for the given shape (train/prefill: full seq; decode:
    one token with positions at cache end)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    batch: dict[str, Any] = {}
    if cfg.embed_inputs:
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.mrope:
        batch["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    elif shape.kind == "decode":
        batch["positions"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch


def batch_shardings(cfg, shape: ShapeConfig, rules: AxisRules) -> dict:
    batch_axes = rules.rules.get("batch")
    def spec_for(key, leaf):
        if key == "positions" and cfg.mrope:
            return resolve_pspec(leaf.shape, (None, "batch", None), rules)
        if key == "embeds":
            return resolve_pspec(leaf.shape, ("batch", None, None), rules)
        return resolve_pspec(leaf.shape, ("batch",) + (None,) * (len(leaf.shape) - 1),
                             rules)
    specs = input_specs(cfg, shape)
    return {k: NamedSharding(rules.mesh, spec_for(k, v))
            for k, v in specs.items()}


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

_CACHE_LOGICAL = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
    "pos": ("layers", "batch"),
    "h": ("layers", "batch", "d_inner", None),
    "conv": ("layers", "batch", None, "d_inner"),
    "s": ("layers", "batch", None, None, None),
    "last": ("layers", "batch", None),
}


def cache_abstract(cfg, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: D.init_cache(cfg, shape.global_batch, shape.seq_len))


def cache_shardings(cfg, cache_abs, rules: AxisRules):
    def walk(node):
        if isinstance(node, dict):
            return {k: (walk(v) if isinstance(v, dict) else _leaf(k, v))
                    for k, v in node.items()}
        return node
    def _leaf(name, leaf):
        logical = _CACHE_LOGICAL.get(name)
        if logical is None or len(logical) != len(leaf.shape):
            logical = ("layers",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(rules.mesh, resolve_pspec(leaf.shape, logical, rules))
    return walk(cache_abs)


# ---------------------------------------------------------------------------
# rules per cell
# ---------------------------------------------------------------------------

def rules_for(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig) -> AxisRules:
    """Sharding rules per cell.

    - batch over (pod, data); TP over model.
    - KV heads replicated (head counts are rarely divisible by 16; the
      GQA expansion gather keeps per-shard locality — DESIGN §5). The KV
      *cache* therefore shards its sequence dim over the model axis
      (flash-decoding style partial softmax), and for long_500k (batch=1)
      over (data, model) — 256-way sequence sharding of the 500k cache.
    """
    if shape.name == "long_500k":
        r = seq_sharded_rules(mesh)
    else:
        r = default_rules(mesh)
    rules = dict(r.rules)
    rules["kv_heads"] = None
    if shape.kind in ("decode", "prefill"):
        prev = rules.get("cache_seq")
        prev_axes = (prev,) if isinstance(prev, str) else tuple(prev or ())
        model = (r.model_axis,) if r.model_axis else ()
        rules["cache_seq"] = prev_axes + model or None
    return AxisRules(rules, mesh=r.mesh, batch_axes=r.batch_axes,
                     model_axis=r.model_axis)


# ---------------------------------------------------------------------------
# step builders (the functions the dry-run lowers)
# ---------------------------------------------------------------------------

def make_train_cell(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules,
                    sparse: Optional[SparseUpdateConfig] = None,
                    optimizer: Optional[OptimizerConfig] = None):
    """Returns (step_fn, abstract_state, state_shardings, abstract_batch,
    batch_shardings) for a training cell."""
    from repro.train.steps import make_train_state, make_train_step

    sparse = sparse if sparse is not None else SparseUpdateConfig(
        update_ratio=0.2, num_update_layers=_default_k(cfg), channel_block=128)
    optimizer = optimizer or OptimizerConfig(kind="sgd", learning_rate=0.01,
                                             warmup_steps=100, decay_steps=10_000)
    tc = TrainConfig(model=cfg, shape=shape, sparse=sparse, optimizer=optimizer)

    with use_rules(rules):
        # abstract state (random selection — magnitude needs real weights)
        def mk(key):
            state, _ = make_train_state(tc, key, selection_init="random")
            return state
        state_abs = jax.eval_shape(mk, jax.random.PRNGKey(0))
        # the plan is static metadata — built concretely under the rules
        from repro.core.selection import build_plan
        plan = build_plan(cfg, sparse, shape.global_batch * shape.seq_len)
        step_fn = make_train_step(tc, plan)

    state_sh = state_shardings(cfg, plan, state_abs, rules)
    batch_abs = input_specs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, rules)
    return step_fn, state_abs, state_sh, batch_abs, batch_sh, plan


def _default_k(cfg) -> int:
    """Default: train the last quarter of scan blocks (the paper's
    as-many-later-layers-as-fit; budget solving is exercised separately)."""
    segs = T.segment_layout(cfg)
    total = sum(s.steps for s in segs)
    return max(1, total // 4)


def state_shardings(cfg, plan, state_abs, rules: AxisRules):
    logical = param_logical_specs(cfg)

    def shard_params(tree, logical_tree):
        if tree is None:
            return None
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = shard_params(v, logical_tree.get(k, {}))
            else:
                lg = logical_tree.get(k)
                if lg is None or len(lg) != len(v.shape):
                    lg = (None,) * len(v.shape)
                out[k] = NamedSharding(rules.mesh,
                                       resolve_pspec(v.shape, lg, rules))
        return out

    sh = {}
    sh["step"] = _replicated(rules)
    sh["rng"] = _replicated(rules)
    sh["params_trainable"] = shard_params(state_abs["params_trainable"], logical)
    sh["params_frozen"] = shard_params(state_abs["params_frozen"], logical)
    opt = state_abs["opt"]
    sh["opt"] = jax.tree.map(lambda _: None, opt) if not opt else {
        k: shard_params(v, logical) for k, v in opt.items()}
    sh["sel_idx"] = replicate_tree(state_abs["sel_idx"], rules) \
        if state_abs["sel_idx"] is not None else None
    return sh


def make_decode_cell(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    """serve_step for decode cells: one new token against a seq_len cache."""
    from repro.models.registry import abstract_params

    params_abs = abstract_params(cfg)
    logical = param_logical_specs(cfg)
    params_sh = tree_shardings(params_abs, logical, rules)
    cache_abs = cache_abstract(cfg, shape)
    cache_sh = cache_shardings(cfg, cache_abs, rules)
    batch_abs = input_specs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, rules)

    def serve_step(params, batch, cache):
        return D.decode_step(cfg, params, batch, cache)

    return serve_step, (params_abs, batch_abs, cache_abs), \
        (params_sh, batch_sh, cache_sh)


def make_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    from repro.models.registry import abstract_params

    params_abs = abstract_params(cfg)
    logical = param_logical_specs(cfg)
    params_sh = tree_shardings(params_abs, logical, rules)
    batch_abs = input_specs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, rules)

    def prefill_step(params, batch):
        return D.prefill(cfg, params, batch)

    return prefill_step, (params_abs, batch_abs), (params_sh, batch_sh)
