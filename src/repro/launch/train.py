"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --update-ratio 0.2 --update-layers 2 --ckpt-dir /tmp/run1

Runs the DGSU fine-tuning loop with checkpoint/restart (auto-resume from
the latest checkpoint in --ckpt-dir), preemption handling (SIGTERM ->
emergency save), and straggler monitoring. On a real TPU pod the same
entrypoint runs under `jax.distributed.initialize()` with the production
mesh; on CPU it uses a debug mesh (or no mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import (OptimizerConfig, ShapeConfig, SparseUpdateConfig,
                           TrainConfig, get_config, get_smoke_config)
from repro.data import lm_batches
from repro.runtime import RestartableLoop, StragglerMonitor
from repro.train import make_train_state, make_train_step


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--dense", action="store_true", help="disable DGSU")
    ap.add_argument("--compact-grads", action="store_true",
                    help="compact-gradient path: never scatter a full-shape "
                         "dW; optimizer updates gathered blocks only")
    ap.add_argument("--update-ratio", type=float, default=0.2)
    ap.add_argument("--update-layers", type=int, default=0,
                    help="last-K scan blocks (0 = solve from budget)")
    ap.add_argument("--memory-budget-mb", type=float, default=0.0)
    ap.add_argument("--channel-block", type=int, default=16)
    ap.add_argument("--phase-j", type=int, default=10)
    ap.add_argument("--phase-k", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    sparse = SparseUpdateConfig(
        enabled=not args.dense,
        update_ratio=args.update_ratio,
        num_update_layers=args.update_layers,
        memory_budget_bytes=int(args.memory_budget_mb * 2**20),
        channel_block=args.channel_block,
        phase_fixed_early=args.phase_j,
        phase_dynamic=args.phase_k,
        phase_fixed_late=max(0, args.steps - args.phase_j - args.phase_k),
        seed=args.seed,
    )
    tc = TrainConfig(
        model=cfg, shape=shape, sparse=sparse,
        optimizer=OptimizerConfig(kind=args.optimizer, learning_rate=args.lr,
                                  warmup_steps=min(20, args.steps // 10),
                                  decay_steps=args.steps),
        steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir, seed=args.seed,
        compact_grads=args.compact_grads and not args.dense)

    key = jax.random.PRNGKey(args.seed)
    state, plan = make_train_state(tc, key)
    if not args.dense:
        from repro.core import selected_fraction
        print(f"[train] DGSU plan: trainable steps/segment={plan.seg_trainable} "
              f"ratio={args.update_ratio} -> "
              f"{100*selected_fraction(plan, cfg):.2f}% of params per iter")
    step_raw = make_train_step(tc, plan, donate=True)
    step_fn = jax.jit(step_raw, donate_argnums=step_raw.donate_argnums)

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            state, meta = mgr.restore(latest, target=state)
            start = int(meta["step"])
            print(f"[train] resumed from step {start}")

    data = lm_batches(shape.global_batch, shape.seq_len, cfg.vocab_size,
                      seed=args.seed, start_step=start)
    monitor = StragglerMonitor(
        on_straggler=lambda s, d, m: print(
            f"[straggler] step {s}: {d*1e3:.0f}ms vs median {m*1e3:.0f}ms"))

    def on_metrics(step, metrics):
        if step % args.log_every == 0 or step == args.steps:
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f}", flush=True)

    def wrapped_step(state, batch):
        return step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})

    if mgr is not None:
        loop = RestartableLoop(mgr, state, args.steps,
                               checkpoint_every=args.ckpt_every,
                               straggler=monitor)
        result = loop.run(wrapped_step, data, start_step=start,
                          on_metrics=on_metrics)
        print(f"[train] done at step {result['step']}; "
              f"stragglers={len(result['stragglers'])} "
              f"emergency={result['emergency']}")
    else:
        for step, batch in zip(range(start, args.steps), data):
            t0 = time.perf_counter()
            state, metrics = wrapped_step(state, batch)
            monitor.record(time.perf_counter() - t0)
            on_metrics(step + 1, metrics)
        print("[train] done")


if __name__ == "__main__":
    main()
