"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (device count is locked at first
jax init, and only the dry-run forces 512 host devices)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod.

    Axes: data (DP, gradient reduction), model (TP/EP); multi-pod adds a
    leading pod axis (DP across DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    return make_mesh((n_data, n_model), ("data", "model"))


def make_serve_mesh(n_model: int = 1):
    """(1, n_model) serve mesh over the FIRST n_model local devices.

    Unlike `make_mesh` (which lays out every device), serving wants exactly
    the shard count asked for — e.g. 4 pool shards on an 8-device host —
    so the mesh is built from an explicit device subset."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < n_model:
        raise ValueError(
            f"serve mesh wants {n_model} model shards but only "
            f"{len(devs)} device(s) exist")
    return Mesh(np.asarray(devs[:n_model]).reshape(1, n_model),
                ("data", "model"))
