"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (device count is locked at first
jax init, and only the dry-run forces 512 host devices)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod.

    Axes: data (DP, gradient reduction), model (TP/EP); multi-pod adds a
    leading pod axis (DP across DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    return make_mesh((n_data, n_model), ("data", "model"))
