"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192.

vocab=202048, MoE 16 routed experts top-1 + 1 shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. Every layer MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,                 # per-expert hidden
    vocab_size=202_048,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1, layout="all"),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=256,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=500_000.0,
        moe=MoEConfig(num_experts=4, top_k=1, num_shared_experts=1, layout="all"),
        dtype="float32",
    )
