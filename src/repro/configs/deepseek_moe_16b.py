"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400.

MoE: 2 shared + 64 routed experts, top-6, fine-grained [arXiv:2401.06066; hf].
First layer is a dense FFN (d_ff dense = 64*1408/ ... deepseek uses 10944
dense first layer; we use num_experts*d_ff-equivalent? Faithful: dense first
layer with d_ff_dense = 10944).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                 # per-expert hidden
    vocab_size=102_400,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  layout="all_but_first"),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=32,
        vocab_size=256,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=10_000.0,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=2,
                      layout="all_but_first"),
        dtype="float32",
    )
