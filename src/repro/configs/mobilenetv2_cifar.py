"""Paper-faithful reproduction config: MobileNetV2 + GroupNorm for CIFAR-like
10-class transfer under a 256KB budget (Dynamic Gradient Sparse Update).

Not one of the 10 assigned LM archs — this is the paper's own experiment.
The CNN config is a separate dataclass (conv stacks don't fit ModelConfig).
"""
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class MobileNetV2Config:
    name: str = "mobilenetv2-cifar"
    num_classes: int = 10
    width_mult: float = 1.0
    img_size: int = 224
    in_channels: int = 3
    gn_groups: int = 8
    # (expansion t, out channels c, repeats n, stride s) — MobileNetV2 table 2
    inverted_residual_setting: Sequence[tuple] = (
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    )
    stem_channels: int = 32
    head_channels: int = 1280
    dtype: str = "float32"


CONFIG = MobileNetV2Config()


def smoke_config() -> MobileNetV2Config:
    return MobileNetV2Config(
        name="mobilenetv2-smoke",
        num_classes=10,
        width_mult=0.25,
        img_size=32,
        gn_groups=2,
        inverted_residual_setting=(
            (1, 8, 1, 1),
            (6, 16, 2, 2),
            (6, 24, 2, 2),
        ),
        stem_channels=8,
        head_channels=64,
    )
