"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE, dynamic resolution [arXiv:2409.12191; hf]. The vision frontend is a
STUB: ``input_specs`` provides precomputed patch embeddings plus 3-component
M-RoPE position ids (temporal, height, width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    embed_inputs=True,    # patch/text embeddings from the stub frontend
    mrope=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=1_000_000.0,
        embed_inputs=True,
        mrope=True,
        dtype="float32",
    )
