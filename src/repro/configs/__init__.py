from repro.configs.base import (
    ARCH_IDS,
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RWKVConfig,
    ShapeConfig,
    SparseUpdateConfig,
    SSMConfig,
    TrainConfig,
    all_cells,
    cell_is_skipped,
    get_config,
    get_smoke_config,
    with_overrides,
)

__all__ = [
    "ARCH_IDS", "LONG_CONTEXT_ARCHS", "SHAPES", "ModelConfig", "MoEConfig",
    "OptimizerConfig", "RWKVConfig", "ShapeConfig", "SparseUpdateConfig",
    "SSMConfig", "TrainConfig", "all_cells", "cell_is_skipped", "get_config",
    "get_smoke_config", "with_overrides",
]
