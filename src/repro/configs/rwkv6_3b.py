"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.

Finch — data-dependent decay [arXiv:2404.05892; hf]. head_dim=64 (40 heads).
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65_536,
    mlp_kind="rwkv_channel_mix",
    norm_kind="layernorm",
    rwkv=RWKVConfig(head_dim=64),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=128,
        vocab_size=256,
        mlp_kind="rwkv_channel_mix",
        norm_kind="layernorm",
        rwkv=RWKVConfig(head_dim=16),
        dtype="float32",
    )
