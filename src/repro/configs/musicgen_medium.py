"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.

Decoder-only LM over EnCodec tokens [arXiv:2306.05284; hf]. The EnCodec
frontend is a STUB: ``input_specs`` provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp_kind="gelu",
    norm_kind="layernorm",
    rope_theta=10_000.0,
    embed_inputs=True,   # frame embeddings from the (stubbed) EnCodec frontend
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="audio",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        mlp_kind="gelu",
        norm_kind="layernorm",
        rope_theta=10_000.0,
        embed_inputs=True,
        dtype="float32",
    )
