"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.

GQA, squared-ReLU MLP [arXiv:2402.16819; unverified].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256_000,
    mlp_kind="sq_relu",
    norm_kind="layernorm",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=256,
        mlp_kind="sq_relu",
        norm_kind="layernorm",
        rope_theta=10_000.0,
        dtype="float32",
    )
