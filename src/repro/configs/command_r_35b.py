"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    mlp_kind="swiglu",
    norm_kind="layernorm",   # cohere uses LN (no-bias handled in layers)
    rope_theta=8e6,
    tie_embeddings=True,     # command-r ties input/output embeddings
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        mlp_kind="swiglu",
        norm_kind="layernorm",
        rope_theta=8e6,
        tie_embeddings=True,
        dtype="float32",
    )
