"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576.

vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf]. Layout: 9 super-blocks of 8 layers; within each
block, layer index 3 is attention, the other 7 are Mamba; MoE replaces the
FFN on every other layer (odd in-block indices).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,                # per-expert hidden
    vocab_size=65_536,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,       # jamba attn uses no rope in v1; 1.5 uses none either — kept for API uniformity
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared_experts=0, layout="every_2"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        num_layers=8,          # one super-block
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=256,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=10_000.0,
        attn_every=8,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=0, layout="every_2"),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        dtype="float32",
    )
