"""Config system: dataclass model/arch configs + input-shape registry.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (full-size, used only by the dry-run via ShapeDtypeStruct) and
``smoke_config()`` (reduced same-family config instantiable on CPU).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int              # routed experts
    top_k: int
    num_shared_experts: int = 0   # always-on experts (deepseek/llama4 style)
    capacity_factor: float = 1.25
    # which layers are MoE: "all", "every_2", "all_but_first"
    layout: str = "all"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block config (jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    mlp_kind: str = "swiglu"      # swiglu | sq_relu | gelu
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm
    rope_theta: float = 1e6
    # attention pattern: "full" | "local:global:<L>:<G>" (L local then 1 global
    # per period) with sliding window below
    attn_pattern: str = "full"
    sliding_window: int = 0
    # hybrid interleave: attention every `attn_every` layers (jamba: 8), rest SSM
    attn_every: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    tie_embeddings: bool = False
    # modality frontend stub: model takes precomputed embeddings instead of ids
    embed_inputs: bool = False
    # M-RoPE (qwen2-vl): rope over 3 position coordinates
    mrope: bool = False
    dtype: str = "bfloat16"

    # -- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for roofline
        MODEL_FLOPS and memory accounting)."""
        from repro.models.registry import param_count  # lazy, avoids cycle
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import param_count
        return param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned; seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

# archs allowed to run long_500k (sub-quadratic path exists)
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "jamba-1.5-large-398b", "gemma3-4b")

ARCH_IDS = (
    "musicgen-medium",
    "command-r-35b",
    "llama3-8b",
    "nemotron-4-15b",
    "gemma3-4b",
    "deepseek-moe-16b",
    "llama4-scout-17b-a16e",
    "jamba-1.5-large-398b",
    "qwen2-vl-7b",
    "rwkv6-3b",
)

_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "command-r-35b": "command_r_35b",
    "llama3-8b": "llama3_8b",
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma3-4b": "gemma3_4b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-3b": "rwkv6_3b",
    "mobilenetv2-cifar": "mobilenetv2_cifar",
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()


def cell_is_skipped(arch_id: str, shape_name: str) -> Optional[str]:
    """Return a skip-reason string if (arch, shape) is not runnable."""
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_ARCHS:
        return "pure full-attention arch: no sub-quadratic path for 500k decode"
    return None


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]


# ---------------------------------------------------------------------------
# Training / sparse-update config (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SparseUpdateConfig:
    """Algorithm 1 knobs + TPU-block granularity."""
    enabled: bool = True
    update_ratio: float = 0.2          # r: fraction of channel blocks per layer
    num_update_layers: int = 0         # K: last-K blocks trainable (0 = solve from budget)
    memory_budget_bytes: int = 0       # M: per-device budget (0 = no constraint)
    channel_block: int = 128           # TPU adaptation: selection granularity
    phase_fixed_early: int = 10        # j (in steps or epochs; trainer decides)
    phase_dynamic: int = 20            # k
    phase_fixed_late: int = 20         # l
    seed: int = 0
    update_embeddings: bool = False    # embeddings/lm_head frozen by default
    update_norms: bool = False         # paper freezes GN; we freeze norms


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"                  # sgd | momentum | adamw  (paper: sgd m=0)
    learning_rate: float = 0.1
    momentum: float = 0.0
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    warmup_steps: int = 0
    decay_steps: int = 0               # cosine decay horizon (0 = constant)
    grad_clip: float = 0.0


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: ShapeConfig
    sparse: SparseUpdateConfig = field(default_factory=SparseUpdateConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    remat: str = "selected"            # none | selected | full
    # compact-gradient path: thread the compact per-block dW through
    # clipping/optimizer/update without ever scattering a full-shape dW
    # (core.sparse_update docstring has the equivalence guarantees)
    compact_grads: bool = False
    seed: int = 0


def with_overrides(cfg, **kw):
    return replace(cfg, **kw)
