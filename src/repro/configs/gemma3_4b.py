"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt; unverified].
head_dim derived = 320. Sliding window 1024 on local layers.

Layer layout: scan over 5 super-blocks of (5 local + 1 global) = 30 layers,
then 4 explicit local layers (34 total); globals at depths 5,11,17,23,29.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262_144,
    mlp_kind="gelu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    attn_pattern="local_global:5:1",
    sliding_window=1024,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke",
        family="dense",
        num_layers=6,          # one local:global period
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp_kind="gelu",
        norm_kind="rmsnorm",
        rope_theta=1_000_000.0,
        attn_pattern="local_global:5:1",
        sliding_window=16,
        tie_embeddings=True,
        dtype="float32",
    )
