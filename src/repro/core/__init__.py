"""The paper's contribution: dynamic gradient sparse update.

- sparse_update: sparse matmul (compact dW for selected channel blocks),
  frozen/trainable layer-stack splitting
- selection: later-layers-first + constant-ratio channel-block selection,
  memory-budget solver
- schedule: Algorithm 1's fixed/dynamic/fixed three-phase schedule
- memory: per-device training-memory model (the 256KB budget, scaled)
- pruning: offline channel + pattern pruning (CNN reproduction path)
- act_prune: ZeBRA block activation pruning
- distill: vanilla KD

Submodules importing the model zoo are loaded lazily (models import
core.sparse_update, so eager imports here would cycle).
"""
import importlib

from repro.core.sparse_update import (SelSpec, smm, split_stack, merge_stack,
                                      use_kernels)

_LAZY = {
    "DeltaState": ("repro.core.delta", "DeltaState"),
    "apply_delta_tree": ("repro.core.delta", "apply_delta_tree"),
    "extract_delta_tree": ("repro.core.delta", "extract_delta_tree"),
    "zeros_delta_tree": ("repro.core.delta", "zeros_delta_tree"),
    "decode_delta_spec": ("repro.core.delta", "decode_delta_spec"),
    "SelectionPlan": ("repro.core.selection", "SelectionPlan"),
    "build_plan": ("repro.core.selection", "build_plan"),
    "random_selection": ("repro.core.selection", "random_selection"),
    "magnitude_selection": ("repro.core.selection", "magnitude_selection"),
    "selected_fraction": ("repro.core.selection", "selected_fraction"),
    "phase_of": ("repro.core.schedule", "phase_of"),
    "maybe_reselect": ("repro.core.schedule", "maybe_reselect"),
    "coverage_after": ("repro.core.schedule", "coverage_after"),
    "memory": ("repro.core.memory", None),
    "act_prune": ("repro.core.act_prune", None),
    "pruning": ("repro.core.pruning", None),
    "distill": ("repro.core.distill", None),
}


def __getattr__(name):
    if name in _LAZY:
        mod_name, attr = _LAZY[name]
        mod = importlib.import_module(mod_name)
        return getattr(mod, attr) if attr else mod
    raise AttributeError(name)
