"""Vanilla knowledge distillation (paper §IV-D: recover post-pruning
accuracy before transfer, VanillaKD [15])."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss(student_logits, teacher_logits, temperature: float = 4.0):
    """KL(teacher || student) at temperature T, scaled by T^2."""
    t = temperature
    sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tp = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    return (t * t) * jnp.mean(jnp.sum(tp * (jnp.log(tp + 1e-9) - sp), axis=-1))


def combined_kd_loss(student_logits, teacher_logits, labels,
                     alpha: float = 0.5, temperature: float = 4.0):
    """alpha * KD + (1-alpha) * CE."""
    lse = jax.nn.logsumexp(student_logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(student_logits.astype(jnp.float32),
                               labels[:, None], axis=-1)[:, 0]
    ce = jnp.mean(lse - gold)
    return alpha * kd_loss(student_logits, teacher_logits, temperature) + \
        (1 - alpha) * ce
