"""Per-device training-memory model — the paper's 256KB budget, scaled.

Estimates the extra memory backprop needs (the paper's "extra memory"
column in Table II): saved activations for trainable layers + gradient and
optimizer-state buffers for selected params. Frozen front layers contribute
nothing (their activations are never saved) — that is the paper's 98%
feature-memory saving.

The model is analytic (used by the budget solver before any tracing); the
dry-run's compiled memory_analysis() is the ground truth it is validated
against (tests/test_memory.py).
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, SparseUpdateConfig


def _bytes(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dtype]


def activation_bytes_per_layer(cfg: ModelConfig, tokens_per_device: int) -> int:
    """Saved-for-backward bytes per trainable scan-step under per-layer remat
    (the scan carry [B,S,d] plus the per-step remat checkpoint)."""
    d = cfg.d_model
    by = _bytes(cfg.dtype)
    per_layer = tokens_per_device * d * by          # carry checkpoint
    if cfg.family == "hybrid":
        per_layer *= cfg.attn_every                 # super-block = N sublayers
    elif cfg.attn_pattern.startswith("local_global"):
        _, l, g = cfg.attn_pattern.split(":")
        per_layer *= int(l) + int(g)
    return per_layer


def trainable_param_bytes(cfg: ModelConfig, sp: SparseUpdateConfig,
                          k_steps: int) -> dict:
    """Gradient + optimizer-state bytes for last-k_steps trainable layers
    with channel ratio r (selected blocks only are optimizer-tracked)."""
    from repro.models.registry import abstract_params
    from repro.models import transformer as T

    abs_params = abstract_params(cfg)
    segs = T.segment_layout(cfg)
    by = _bytes(cfg.dtype)
    remaining = k_steps
    grad_full = 0
    grad_sel = 0
    for seg in reversed(segs):
        take = min(seg.steps, remaining)
        remaining -= take
        if take == 0:
            continue
        stack = abs_params["segments"][seg.name]
        per_step = sum(x.size for x in jax.tree.leaves(stack)) // seg.steps
        grad_full += per_step * take
        grad_sel += int(per_step * take * sp.update_ratio)
    return {
        "grad_bytes_full": grad_full * by,
        "grad_bytes_selected": grad_sel * by,
        "opt_bytes_selected": grad_sel * by,   # 1x for momentum; 0 for sgd
    }


def training_extra_bytes(cfg: ModelConfig, sp: SparseUpdateConfig,
                         k_steps: int, tokens_per_device: int,
                         optimizer_slots: int = 0) -> int:
    """The paper's 'extra memory' for one update iteration."""
    act = activation_bytes_per_layer(cfg, tokens_per_device) * k_steps
    tp = trainable_param_bytes(cfg, sp, k_steps)
    grads = tp["grad_bytes_selected"]
    opt = tp["opt_bytes_selected"] * optimizer_slots
    return act + grads + opt


def dense_training_extra_bytes(cfg: ModelConfig, tokens_per_device: int,
                               optimizer_slots: int = 1) -> int:
    """Baseline: full fine-tune (all layers, dense grads)."""
    from repro.models.registry import abstract_params
    segs_total = sum(s.steps for s in __import__(
        "repro.models.transformer", fromlist=["segment_layout"]
    ).segment_layout(cfg))
    n_params = sum(x.size for x in jax.tree.leaves(abstract_params(cfg)))
    by = _bytes(cfg.dtype)
    act = activation_bytes_per_layer(cfg, tokens_per_device) * segs_total
    return act + n_params * by * (1 + optimizer_slots)


def solve_max_layers(cfg: ModelConfig, sp: SparseUpdateConfig,
                     tokens_per_device: int, optimizer_slots: int = 0,
                     *, strict: bool = False) -> int:
    """Largest last-K (scan steps) whose extra memory fits sp.memory_budget_bytes
    — the paper's 'update as many (later) layers as the budget allows'.

    If even K=1 exceeds the budget, the solver cannot honor it: it warns and
    returns 1 (training needs at least one trainable step), or raises under
    ``strict=True`` — it never silently blows the 256KB-style budget."""
    from repro.models import transformer as T
    total = sum(s.steps for s in T.segment_layout(cfg))
    best = 0
    for k in range(1, total + 1):
        if training_extra_bytes(cfg, sp, k, tokens_per_device,
                                optimizer_slots) <= sp.memory_budget_bytes:
            best = k
        else:
            break
    if best == 0:
        need = training_extra_bytes(cfg, sp, 1, tokens_per_device,
                                    optimizer_slots)
        msg = (f"memory budget {sp.memory_budget_bytes}B cannot fit even one "
               f"trainable scan step of {cfg.name} (needs {need}B at "
               f"{tokens_per_device} tokens/device)")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg + "; falling back to K=1 over budget", stacklevel=2)
        return 1
    return best
