"""First-class compact parameter deltas — the `[K, n_shards, n_sel, block]`
representation that the compact-gradient train step used to hold only
transiently, extracted into a shared abstraction consumed by BOTH halves of
the system:

- **train half**: an online train wave materializes `base + delta` for the
  trainable suffix, runs the existing 2-launch compact train step, and
  re-extracts the delta (`apply_delta_tree` / `extract_delta_tree`). The
  base weights are never written — bitwise identical before and after.
- **serve half**: decode applies the same delta as a *gather-add at matmul
  time* (`repro.models.common.delta_matmul_add`): the per-user contribution
  `x @ delta` lands only in the selected output-channel blocks, so no dense
  per-user weight copy ever exists and user deltas ride the jitted
  `paged_step` as batch-row data (no per-user retrace).

Value dtype is float32 throughout: a delta is the exact difference of two
param-dtype (bf16) tensors, which f32 represents exactly, so
`scatter(gather(base) + delta)` reconstructs the trained weights bitwise.

Shapes, per selectable leaf of a trainable segment stack `[K, *lead, N]`:

    idx   [K, n_shards, n_sel]                   int32 block ids per shard
    vals  [K, *lead, n_shards, n_sel, block]     f32 selected-block delta
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_update import (SelSpec, gather_param_blocks,
                                      scatter_param_blocks)

__all__ = [
    "DeltaState", "DECODE_DELTA_PARENTS", "apply_delta_tree",
    "decode_delta_spec", "extract_delta_tree", "zeros_delta_tree",
]

# sublayer dicts whose selectable matmuls the serve-time gather-add covers:
# plain [B,S,d] x [d,N] projections of attention and dense MLP blocks.
# Mixer-internal matmuls (mamba in_proj/out_proj, rwkv time/channel mix) and
# expert-batched MoE weights keep delta=None on the decode path.
DECODE_DELTA_PARENTS = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
}


@dataclasses.dataclass
class DeltaState:
    """One user's compact parameter delta against a fixed base model.

    `idx` / `vals` are per-segment trees mirroring the (pruned) selection
    spec; leaves may be numpy (host-resident store entry) or jnp (device).
    """
    idx: dict           # seg -> nested {leaf: [K, n_shards, n_sel] int32}
    vals: dict          # seg -> nested {leaf: [K, *lead, h, n_sel, block] f32}

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in
                   jax.tree.leaves((self.idx, self.vals)))

    def to_tree(self) -> dict:
        """Checkpoint-friendly pytree (plain nested dicts, no None segs)."""
        return {"idx": self.idx, "vals": self.vals}

    @classmethod
    def from_tree(cls, tree: dict) -> "DeltaState":
        return cls(idx=tree["idx"], vals=tree["vals"])


def _spec_leaves(spec_tree) -> list:
    return jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, SelSpec))


def decode_delta_spec(plan, trainable_segments) -> dict:
    """Prune `plan.spec` to the leaves the decode gather-add can apply:
    2D-per-layer projections under an `attn`/`mlp` sublayer (see
    DECODE_DELTA_PARENTS). Returns {seg: nested {leaf: SelSpec}} with empty
    segments dropped."""
    def walk(spec, stack, parent):
        out = {}
        for name, sub in spec.items():
            if isinstance(sub, dict):
                child = walk(sub, stack[name], name)
                if child:
                    out[name] = child
            elif (name in DECODE_DELTA_PARENTS.get(parent, ())
                  and stack[name].ndim == 3):
                out[name] = sub
        return out

    out = {}
    for seg, spec in plan.spec.items():
        if not plan.seg_trainable.get(seg) or seg not in trainable_segments:
            continue
        pruned = walk(spec, trainable_segments[seg], "")
        if pruned:
            out[seg] = pruned
    return out


def zeros_delta_tree(trainable_segments, idx_tree, spec_tree, xp=np) -> dict:
    """Zero-valued delta `vals` tree matching `spec_tree` (the shape
    `gather_param_blocks` would produce). `xp` picks numpy (host store
    entries) or jnp (device)."""
    def walk(stack, idx, spec):
        if isinstance(spec, SelSpec):
            k = idx.shape[0]
            lead = tuple(stack.shape[1:-1])
            return xp.zeros((k,) + lead + (spec.n_shards, spec.n_sel,
                                           spec.block), xp.float32)
        return {name: walk(stack[name], idx[name], spec[name])
                for name in spec}

    return {seg: walk(trainable_segments[seg], idx_tree[seg], spec)
            for seg, spec in spec_tree.items()}


def apply_delta_tree(trainable_segments, vals_tree, idx_tree, spec_tree):
    """Materialize `base + delta` for the trainable segments: overwrite each
    selected block with `gather(base) + vals` (f32 add, cast back to the
    param dtype). Non-selectable leaves and unselected blocks pass through
    untouched; the base tree itself is never modified."""
    def walk(stack, vals, idx, spec):
        if isinstance(spec, SelSpec):
            base = gather_param_blocks(stack, idx, spec).astype(jnp.float32)
            return scatter_param_blocks(stack, base + vals, idx, spec)
        return {name: (walk(sub, vals[name], idx[name], spec[name])
                       if name in spec else sub)
                for name, sub in stack.items()}

    out = {}
    for seg, stack in trainable_segments.items():
        spec = spec_tree.get(seg)
        if not spec or idx_tree.get(seg) is None or \
                vals_tree.get(seg) is None:
            out[seg] = stack
        else:
            out[seg] = walk(stack, vals_tree[seg], idx_tree[seg], spec)
    return out


def extract_delta_tree(base_segments, new_segments, idx_tree, spec_tree):
    """Inverse of `apply_delta_tree` after training: the compact f32
    difference `gather(new) - gather(base)` per selectable leaf."""
    def walk(base, new, idx, spec):
        if isinstance(spec, SelSpec):
            return (gather_param_blocks(new, idx, spec).astype(jnp.float32)
                    - gather_param_blocks(base, idx, spec).astype(jnp.float32))
        return {name: walk(base[name], new[name], idx[name], spec[name])
                for name in spec}

    return {seg: walk(base_segments[seg], new_segments[seg],
                      idx_tree[seg], spec)
            for seg, spec in spec_tree.items()}
