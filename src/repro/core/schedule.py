"""Algorithm 1 — the three-phase dynamic gradient sparse update schedule.

    phase 0 (steps [0, j)):        fixed selection (model still adapting;
                                   re-randomizing would not help — paper)
    phase 1 (steps [j, j+k)):      DYNAMIC: re-randomize the channel blocks
                                   every iteration, traversing most of the
                                   update layers' parameters over time
    phase 2 (steps [j+k, j+k+l)):  fixed again (convergence fine-tuning)

The selection indices are data, so phase transitions cost nothing and the
same compiled train_step serves all three phases.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SparseUpdateConfig
from repro.core.selection import SelectionPlan, random_selection


def phase_of(step: int, sp: SparseUpdateConfig) -> int:
    if step < sp.phase_fixed_early:
        return 0
    if step < sp.phase_fixed_early + sp.phase_dynamic:
        return 1
    return 2


def maybe_reselect(plan: SelectionPlan, sp: SparseUpdateConfig, sel_idx,
                   step, key):
    """Jit-friendly: returns the selection for `step` — a fresh random
    selection inside the dynamic window, the incoming one otherwise."""
    in_dynamic = jnp.logical_and(step >= sp.phase_fixed_early,
                                 step < sp.phase_fixed_early + sp.phase_dynamic)
    fresh = random_selection(plan, key)

    def pick(old, new):
        if old is None:
            return None
        return jnp.where(in_dynamic, new, old)

    return jax.tree.map(pick, sel_idx, fresh,
                        is_leaf=lambda x: x is None)


def coverage_after(plan: SelectionPlan, sp: SparseUpdateConfig,
                   num_steps: int, key) -> float:
    """Expected fraction of selectable blocks touched at least once after
    `num_steps` (paper Fig. 4 analogue: dynamic >> fixed coverage).

    Fixed phases touch n_sel/n_blocks once; each dynamic step re-draws."""
    from repro.core.sparse_update import SelSpec
    leaves = [l for seg in plan.spec.values()
              for l in jax.tree_util.tree_leaves(
                  seg, is_leaf=lambda x: isinstance(x, SelSpec))]
    if not leaves:
        return 0.0
    dyn_steps = max(0, min(num_steps - sp.phase_fixed_early, sp.phase_dynamic))
    total, covered = 0, 0.0
    for spc in leaves:
        nb = spc.n_blocks * spc.n_shards
        nsel = spc.n_sel * spc.n_shards
        p_fixed = nsel / nb
        # P(block touched) = 1 - (1-p)^dyn for dynamic draws, plus the fixed set
        p_dyn = 1.0 - (1.0 - nsel / nb) ** dyn_steps
        p = p_fixed + (1 - p_fixed) * p_dyn
        covered += p * nb
        total += nb
    return covered / total
