"""Offline pruning (paper §III-A) for the CNN reproduction path.

1. Dependency-aware channel pruning (DepGraph [9], simplified): the
   *hidden* channels of each inverted residual form one dependency group
   (expand-out ∥ depthwise ∥ project-in); groups are scored by mean |w| and
   pruned with per-layer sparsity set by the layer's mean-|w| rank (higher
   layers = more sensitive = pruned less — paper §III-A.1). Same sparsity
   for all filters of a layer (the paper's PE-utilization rule).

2. Pattern-based pruning (PatDNN [10]): every 3x3 depthwise kernel keeps a
   4-entry pattern chosen from a fixed library (best-magnitude match);
   1x1 convs get unstructured magnitude pruning to the target rate.

Both emit masks (semi-structured zeros) — the paper's skip-zero hardware is
an ASIC concern; memory/FLOP savings are reported analytically
(benchmarks/pruning_table.py). Applied on the *pre-training* distribution,
never the target dataset (the paper's realism argument).
"""
from __future__ import annotations

import itertools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# PatDNN-style 4-entry patterns for 3x3 kernels (center always kept)
_PATTERNS = np.array([
    [0, 1, 3, 4], [1, 2, 4, 5], [3, 4, 6, 7], [4, 5, 7, 8],
    [0, 2, 4, 6], [2, 4, 6, 8], [0, 4, 6, 8], [0, 2, 4, 8],
    [1, 3, 4, 5], [3, 4, 5, 7],
])


def channel_group_scores(params, cfg) -> dict[str, np.ndarray]:
    """Mean |w| per hidden-channel group for each inverted-residual block."""
    scores = {}
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        for i in range(n):
            base = f"b{idx}"
            blk = params[base]
            group = np.abs(np.asarray(blk["dw"]["w"], np.float32)).mean((0, 1, 2))
            if "expand" in blk:
                group = group + np.abs(np.asarray(blk["expand"]["w"],
                                                  np.float32)).mean((0, 1, 2))
            group = group + np.abs(np.asarray(blk["project"]["w"],
                                              np.float32)).mean((0, 1)).mean(-1)
            scores[base] = group
            idx += 1
    return scores


def layer_sparsity_targets(params, cfg, global_target: float) -> dict[str, float]:
    """Per-layer sparsity from mean-|w| rank: larger mean |w| (more
    sensitive, typically later layers) -> pruned less (paper §III-A.1)."""
    means = {}
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        for i in range(n):
            base = f"b{idx}"
            means[base] = float(np.abs(np.asarray(
                params[base]["dw"]["w"], np.float32)).mean())
            idx += 1
    order = sorted(means, key=means.get)          # low mean first = prune more
    n_l = len(order)
    targets = {}
    for rank, name in enumerate(order):
        # linear ramp around the global target: [1.3t .. 0.7t]
        targets[name] = float(np.clip(
            global_target * (1.3 - 0.6 * rank / max(1, n_l - 1)), 0.0, 0.95))
    return targets


def channel_prune_masks(params, cfg, global_target: float = 0.4) -> dict:
    """Channel masks per block (1=keep), dependency-consistent across the
    expand/dw/project group."""
    scores = channel_group_scores(params, cfg)
    targets = layer_sparsity_targets(params, cfg, global_target)
    masks = {}
    for base, s in scores.items():
        n = s.shape[0]
        n_prune = int(n * targets[base])
        keep = np.ones(n, bool)
        if n_prune > 0:
            drop = np.argsort(s)[:n_prune]
            keep[drop] = False
        masks[base] = jnp.asarray(keep)
    return masks


def apply_channel_masks(params, masks) -> Any:
    """Zero the pruned hidden channels consistently across the group."""
    params = jax.tree.map(lambda x: x, params)  # copy
    for base, keep in masks.items():
        blk = dict(params[base])
        k = keep.astype(params[base]["dw"]["w"].dtype)
        if "expand" in blk:
            e = dict(blk["expand"]); e["w"] = blk["expand"]["w"] * k
            blk["expand"] = e
        d = dict(blk["dw"]); d["w"] = blk["dw"]["w"] * k
        blk["dw"] = d
        pmask = k[:, None]
        pr = dict(blk["project"]); pr["w"] = blk["project"]["w"] * pmask
        blk["project"] = pr
        params[base] = blk
    return params


def pattern_prune_kernel(w) -> jnp.ndarray:
    """w: [3,3,I,O] -> mask keeping the best 4-entry pattern per (i,o)."""
    flat = np.abs(np.asarray(w, np.float32)).reshape(9, -1)    # [9, I*O]
    pat_sums = np.stack([flat[p].sum(0) for p in _PATTERNS])   # [P, I*O]
    best = pat_sums.argmax(0)                                  # [I*O]
    mask = np.zeros((9, flat.shape[1]), np.float32)
    for pi, p in enumerate(_PATTERNS):
        cols = best == pi
        mask[np.ix_(p, np.where(cols)[0])] = 1.0
    return jnp.asarray(mask.reshape(w.shape))


def unstructured_prune(w, rate: float) -> jnp.ndarray:
    flat = np.abs(np.asarray(w, np.float32)).ravel()
    k = int(len(flat) * rate)
    if k == 0:
        return jnp.ones_like(w)
    thr = np.partition(flat, k)[k]
    return jnp.asarray((np.abs(np.asarray(w)) >= thr).astype(np.float32))


def full_prune(params, cfg, channel_target: float = 0.4,
               pattern: bool = True, unstructured_rate: float = 0.5):
    """Channel + pattern pruning pipeline. Returns (pruned_params, report)."""
    masks = channel_prune_masks(params, cfg, channel_target)
    pruned = apply_channel_masks(params, masks)
    report = {}
    total, zeros = 0, 0
    idx = 0
    for t, c, n, s in cfg.inverted_residual_setting:
        for i in range(n):
            base = f"b{idx}"
            blk = dict(pruned[base])
            if pattern:
                d = dict(blk["dw"])
                d["w"] = d["w"] * pattern_prune_kernel(d["w"])
                blk["dw"] = d
            if unstructured_rate > 0:
                for key in ("expand", "project"):
                    if key in blk:
                        e = dict(blk[key])
                        e["w"] = e["w"] * unstructured_prune(e["w"],
                                                             unstructured_rate)
                        blk[key] = e
            pruned[base] = blk
            idx += 1
    for name in list(pruned):
        if not name.startswith("b"):
            continue
        for sub in pruned[name].values():
            if isinstance(sub, dict) and "w" in sub:
                w = np.asarray(sub["w"])
                total += w.size
                zeros += int((w == 0).sum())
    report["conv_sparsity"] = zeros / max(total, 1)
    report["params_before"] = total
    report["params_after_nonzero"] = total - zeros
    return pruned, report


def conv_flops(cfg, img: int) -> float:
    """Analytic MAC count of MobileNetV2 at resolution img (for the paper's
    FLOP-reduction table)."""
    from repro.models.mobilenet_v2 import _make_divisible
    wm = cfg.width_mult
    flops = 0.0
    res = img // 2
    c_prev = _make_divisible(cfg.stem_channels * wm)
    flops += (img // 2) ** 2 * 9 * 3 * c_prev
    for t, c, n, s in cfg.inverted_residual_setting:
        c_out = _make_divisible(c * wm)
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = c_prev * t
            out_res = res // stride
            if t != 1:
                flops += res ** 2 * c_prev * hidden
            flops += out_res ** 2 * 9 * hidden
            flops += out_res ** 2 * hidden * c_out
            res, c_prev = out_res, c_out
    c_head = _make_divisible(cfg.head_channels * max(1.0, wm))
    flops += res ** 2 * c_prev * c_head
    return 2.0 * flops
