"""Block activation pruning (ZeBRA [11], paper §III-A.2).

Zero every `block`-wide run of channels whose max |x| is below the
threshold. Paper settings: block=2, threshold=0.15. The Pallas kernel
version lives in kernels/block_act_prune.py; this module is the jnp
implementation (also the kernel's oracle)."""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp


def block_act_prune(x, threshold: float = 0.15, block: int = 2):
    """x: [..., C] -> x with sub-threshold blocks zeroed (C % block == 0)."""
    c = x.shape[-1]
    assert c % block == 0, (c, block)
    xb = x.reshape(x.shape[:-1] + (c // block, block))
    keep = (jnp.abs(xb).max(axis=-1, keepdims=True) >= threshold)
    return (xb * keep.astype(x.dtype)).reshape(x.shape)


def make_act_pruner(threshold: float = 0.15, block: int = 2, use_kernel: bool = False):
    if use_kernel:
        from repro.kernels import ops as kops
        return partial(kops.block_act_prune, threshold=threshold, block=block)
    return partial(block_act_prune, threshold=threshold, block=block)


def block_sparsity(x, threshold: float = 0.15, block: int = 2) -> jnp.ndarray:
    """Fraction of zeroed blocks (the paper's activation-sparsity metric)."""
    c = x.shape[-1]
    xb = x.reshape(x.shape[:-1] + (c // block, block))
    pruned = (jnp.abs(xb).max(axis=-1) < threshold)
    return pruned.mean()
