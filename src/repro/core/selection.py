"""Layer/channel selection (paper §III-B.1) + memory-budget solver.

Selection criterion is the paper's: *later layers first* with a *constant*
channel update ratio `r`, sized so the backward working set fits the memory
budget `M`. No target-dataset statistics are used (the paper's realism
argument vs SparseUpdate/TinyTrain).

TPU adaptation: channels are selected in MXU-aligned blocks, equally many
per TP shard of each weight's output dim (the paper's equal-sparsity-per-PE
rule as TP load balance).
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SparseUpdateConfig
from repro.core.sparse_update import SelSpec
from repro.core import memory as memmod
from repro.models import transformer as T
from repro.models.registry import abstract_params
from repro.sharding import current_rules

# weight leaves that participate in channel selection (out-channel blocks)
SELECTABLE = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
              "in_proj", "out_proj", "wg", "wr"}
# excluded even though matmul-shaped: tiny recurrence/router params
EXCLUDED = {"router", "x_proj", "dt_proj", "A_log", "wA", "wB", "mu", "u",
            "w0", "conv_w"}


@dataclass(frozen=True)
class SelectionPlan:
    """Static plan: which scan-steps are trainable per segment and the
    channel-block spec for every selectable weight leaf."""
    seg_trainable: dict[str, int]          # segment -> trailing steps trainable
    spec: dict[str, Any]                   # segment -> nested {leaf: SelSpec}
    update_ratio: float
    channel_block: int
    seed: int
    update_embeddings: bool = False

    def total_steps(self) -> int:
        return sum(self.seg_trainable.values())


def _largest_divisor_leq(n: int, k: int) -> int:
    for d in range(min(n, k), 0, -1):
        if n % d == 0:
            return d
    return 1


def _sharded_out(path_names: tuple[str, ...], leaf_shape) -> int:
    """TP shard count of the out dim, from the logical specs."""
    from repro.models.specs import _leaf_spec

    class _L:  # minimal shim with .ndim
        def __init__(s, nd): s.ndim = nd
    spec = _leaf_spec(path_names, _L(len(leaf_shape)))
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return 1
    mesh_axis = rules.rules.get(spec[-1]) if spec[-1] else None
    if mesh_axis is None:
        return 1
    size = rules.mesh.shape[mesh_axis]
    return size if leaf_shape[-1] % size == 0 else 1


def _build_spec_tree(cfg, seg_stack_abs, ratio: float, block_req: int,
                     path_prefix: tuple[str, ...] = ()) -> dict:
    """Walk a segment's abstract stacked params; SelSpec per selectable leaf."""
    out = {}
    for name, sub in seg_stack_abs.items():
        if isinstance(sub, dict):
            child = _build_spec_tree(cfg, sub, ratio, block_req,
                                     path_prefix + (name,))
            if child:
                out[name] = child
            continue
        if name in EXCLUDED or name not in SELECTABLE or sub.ndim < 3:
            continue  # ndim<3: unstacked 1D bias etc (stacked 2D weight = ndim 3)
        out_dim = sub.shape[-1]
        n_shards = _sharded_out(path_prefix + (name,), sub.shape[1:])
        loc = out_dim // n_shards
        block = _largest_divisor_leq(loc, block_req)
        n_blocks = loc // block
        n_sel = max(1, int(round(ratio * n_blocks)))
        out[name] = SelSpec(block=block, n_shards=n_shards, n_sel=n_sel,
                            n_blocks=n_blocks)
    return out


def build_plan(cfg: ModelConfig, sp: SparseUpdateConfig,
               per_device_batch_tokens: int = 0) -> SelectionPlan:
    """Build the selection plan. If sp.num_update_layers == 0, solve the
    largest last-K under sp.memory_budget_bytes via the memory model."""
    segs = T.segment_layout(cfg)
    abs_params = abstract_params(cfg)

    spec = {}
    for seg in segs:
        spec[seg.name] = _build_spec_tree(cfg, abs_params["segments"][seg.name],
                                          sp.update_ratio, sp.channel_block)

    total_steps = sum(s.steps for s in segs)
    if sp.num_update_layers > 0:
        k_steps = min(sp.num_update_layers, total_steps)
    elif sp.memory_budget_bytes > 0:
        k_steps = memmod.solve_max_layers(cfg, sp, per_device_batch_tokens)
    else:
        k_steps = total_steps

    # distribute trainable steps from the END (later layers first — paper)
    seg_trainable = {}
    remaining = k_steps
    for seg in reversed(segs):
        take = min(seg.steps, remaining)
        seg_trainable[seg.name] = take
        remaining -= take
    return SelectionPlan(seg_trainable=seg_trainable, spec=spec,
                         update_ratio=sp.update_ratio,
                         channel_block=sp.channel_block, seed=sp.seed,
                         update_embeddings=sp.update_embeddings)


# ---------------------------------------------------------------------------
# index generation
# ---------------------------------------------------------------------------

def _rand_idx(key, steps: int, spec: SelSpec):
    """Random n_sel of n_blocks per (step, shard): [steps, n_shards, n_sel]."""
    u = jax.random.uniform(key, (steps, spec.n_shards, spec.n_blocks))
    return jnp.argsort(u, axis=-1)[..., : spec.n_sel].astype(jnp.int32)


def random_selection(plan: SelectionPlan, key) -> dict:
    """Fresh random channel-block selection (used every step of the dynamic
    phase). Returns idx tree: segment -> nested {leaf: [K, n_shards, n_sel]}."""
    idx = {}
    for seg_name, steps in plan.seg_trainable.items():
        if steps == 0:
            idx[seg_name] = None
            continue
        leaves, treedef = jax.tree_util.tree_flatten(
            plan.spec[seg_name], is_leaf=lambda x: isinstance(x, SelSpec))
        # stable across processes (builtin hash() varies with PYTHONHASHSEED,
        # which would break checkpoint-resume selection determinism)
        seg_salt = zlib.crc32(seg_name.encode()) % 2**31
        keys = jax.random.split(jax.random.fold_in(key, seg_salt),
                                max(1, len(leaves)))
        idx_leaves = [_rand_idx(k, steps, sp) for k, sp in zip(keys, leaves)]
        idx[seg_name] = jax.tree_util.tree_unflatten(treedef, idx_leaves)
    return idx


def magnitude_selection(plan: SelectionPlan, params) -> dict:
    """Initial selection: per shard, the n_sel blocks with largest weight L2
    norm (paper's offline importance — no target data needed)."""
    idx = {}
    for seg_name, steps in plan.seg_trainable.items():
        if steps == 0:
            idx[seg_name] = None
            continue
        stack = params["segments"][seg_name]
        k_slice = lambda a: a[a.shape[0] - steps:]
        idx[seg_name] = _magnitude_tree(plan.spec[seg_name], stack, k_slice)
    return idx


def _magnitude_tree(spec_tree, stack, k_slice):
    out = {}
    for name, sub in spec_tree.items():
        if isinstance(sub, dict):
            out[name] = _magnitude_tree(sub, stack[name], k_slice)
            continue
        sp: SelSpec = sub
        w = k_slice(stack[name])                       # [K, ..., out]
        k = w.shape[0]
        wb = w.reshape(k, -1, sp.n_shards, sp.n_blocks, sp.block)
        norms = jnp.sqrt((wb.astype(jnp.float32) ** 2).sum(axis=(1, 4)))
        order = jnp.argsort(-norms, axis=-1)
        out[name] = order[..., : sp.n_sel].astype(jnp.int32)
    return out


def selected_fraction(plan: SelectionPlan, cfg) -> float:
    """Fraction of total params updated per iteration (paper: ~2%)."""
    abs_params = abstract_params(cfg)
    total = sum(x.size for x in jax.tree.leaves(abs_params))
    upd = 0
    for seg_name, steps in plan.seg_trainable.items():
        if steps == 0:
            continue
        stack = abs_params["segments"][seg_name]
        upd += _selected_params(plan.spec[seg_name], stack, steps)
    return upd / total


def _selected_params(spec_tree, stack, steps) -> int:
    n = 0
    for name, sub in spec_tree.items():
        if isinstance(sub, dict):
            n += _selected_params(sub, stack[name], steps)
            continue
        sp: SelSpec = sub
        leaf = stack[name]
        per_step = leaf.size // leaf.shape[0]
        n += int(per_step * steps * (sp.n_sel / sp.n_blocks))
    return n
