"""Dynamic gradient sparse update — the paper's core, as JAX autodiff machinery.

Two mechanisms (paper §III-B):

1. **Layer selection**: only the last-K scan-blocks of the network are
   trainable. Implemented by splitting the stacked layer params into
   (frozen-prefix, trainable-suffix); `jax.grad` w.r.t. the suffix only means
   XLA never materializes backward residuals for the prefix — the paper's
   "discard the corresponding output features" memory saving.

2. **Channel selection**: within trainable layers, each weight's *output
   channel blocks* are selected with ratio r. `smm` (sparse matmul) is a
   drop-in `x @ w` whose custom VJP computes dW **only for the selected
   blocks** (a compact [K, r·N] matmul instead of [K, N]) and scatters into a
   zero buffer. dX is always dense (needed to keep propagating). The block
   granularity (default 128) is the TPU adaptation: MXU-aligned tiles that
   the Pallas kernel (`repro.kernels.masked_dw`) can skip wholesale.

Selection indices are *data* (int32 arrays), so the dynamic phase of
Algorithm 1 re-randomizes them every step without recompilation.

Selection layout: for a weight with output dim N sharded over `n_shards` TP
shards, `idx` has shape [n_shards, n_sel] holding block indices *local to
each shard* — every shard updates the same number of blocks (the paper's
equal-sparsity-per-PE rule, reborn as TP load balance).
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

_FLAGS = threading.local()


class SelSpec(NamedTuple):
    """Static (trace-time) description of one weight's channel selection."""
    block: int        # channels per block
    n_shards: int     # TP shards of the out dim
    n_sel: int        # selected blocks per shard
    n_blocks: int     # total blocks per shard


@contextlib.contextmanager
def use_kernels(enabled: bool = True):
    """Route the compact dW computation through the Pallas kernel."""
    prev = getattr(_FLAGS, "kernels", False)
    _FLAGS.kernels = enabled
    try:
        yield
    finally:
        _FLAGS.kernels = prev


def kernels_enabled() -> bool:
    return getattr(_FLAGS, "kernels", False)


@contextlib.contextmanager
def compact_allreduce(enabled: bool = True):
    """Gradient compression (beyond-paper, EXPERIMENTS.md §Perf): force the
    data-parallel reduction of dW onto the COMPACT selected-block tensor.

    A sharding constraint marks dw_sel as replicated across the DP axes, so
    XLA inserts the cross-data all-reduce there — r x the bytes of the
    full-shape gradient. The scatter to full shape then runs on already-
    replicated operands and needs no further collective."""
    prev = getattr(_FLAGS, "cgr", False)
    _FLAGS.cgr = enabled
    try:
        yield
    finally:
        _FLAGS.cgr = prev


def compact_allreduce_enabled() -> bool:
    return getattr(_FLAGS, "cgr", False)


def compress_grads(grads_segments: dict, sel_idx: dict, spec_tree: dict,
                   logical_tree: Optional[dict] = None):
    """Gradient-compression rewrite (used when compact_allreduce is on):

        dw  ->  scatter(constrain(gather(dw, idx)), idx)

    Selected-block gathers of dw equal dw's only nonzero content, so the
    rewrite is exact. The constraint marks the COMPACT tensor replicated
    across the DP axes (while keeping each leaf's natural TP sharding on its
    other dims, from `logical_tree` = param_logical_specs segments), so XLA
    places the cross-data all-reduce there — r x the full-gradient bytes
    (the paper's selected-channels idea applied to the interconnect)."""
    from repro.sharding import constrain

    def leaf(dw, idx, spec: SelSpec, logical):
        k_steps = dw.shape[0]
        lead = dw.shape[:-1]                   # [K(, E), in]
        dwb = dw.reshape(lead + (spec.n_shards, spec.n_blocks, spec.block))
        # idx: [K, n_shards, n_sel] -> broadcast into the gather
        bidx = idx.reshape((k_steps,) + (1,) * (len(lead) - 1)
                           + (spec.n_shards, spec.n_sel, 1))
        bidx = jnp.broadcast_to(bidx, lead + (spec.n_shards, spec.n_sel,
                                              spec.block))
        dw_sel = jnp.take_along_axis(dwb, bidx, axis=len(lead) + 1)
        # keep the leaf's natural TP sharding on its non-out dims; the out
        # dim's TP sharding (if any) rides the n_shards dim.
        if logical is not None and len(logical) == len(dw.shape):
            in_axes = tuple(logical[:-1])
            out_tp = logical[-1] if spec.n_shards > 1 else None
        else:
            in_axes = ("layers",) + (None,) * (len(lead) - 1)
            out_tp = "ff" if spec.n_shards > 1 else None
        dw_sel = constrain(dw_sel, *in_axes, out_tp, None, None)
        zeros = jnp.zeros_like(dwb)
        dw_new = jnp.put_along_axis(zeros, bidx, dw_sel.astype(dw.dtype),
                                    axis=len(lead) + 1, inplace=False)
        return dw_new.reshape(dw.shape)

    def walk(g, i, s, lg):
        if isinstance(s, SelSpec):
            return leaf(g, i, s, lg)
        if isinstance(s, dict):
            return {k: (walk(g[k], i[k], s[k],
                            (lg or {}).get(k) if isinstance(lg, dict) else None)
                        if k in s else g[k])
                    for k in g}
        return g

    out = {}
    for seg, g in grads_segments.items():
        if sel_idx.get(seg) is None or seg not in spec_tree:
            out[seg] = g
            continue
        lg = (logical_tree or {}).get(seg)
        out[seg] = walk(g, sel_idx[seg], spec_tree[seg], lg)
    return out


# ---------------------------------------------------------------------------
# sparse matmul
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _smm(x, w, idx, spec: SelSpec):
    return jnp.matmul(x, w)


def _smm_fwd(x, w, idx, spec: SelSpec):
    return jnp.matmul(x, w), (x, w, idx)


def _gather_blocks(dy2, idx, spec: SelSpec):
    """dy2: [M, N] -> selected blocks [M, n_shards, n_sel, block]."""
    m = dy2.shape[0]
    dyb = dy2.reshape(m, spec.n_shards, spec.n_blocks, spec.block)
    return jnp.take_along_axis(dyb, idx[None, :, :, None], axis=2)


def _scatter_blocks(dw_sel, idx, spec: SelSpec, k: int, dtype):
    """dw_sel: [K, n_shards, n_sel, block] -> full [K, N] with zeros elsewhere."""
    zeros = jnp.zeros((k, spec.n_shards, spec.n_blocks, spec.block), dtype)
    full = jnp.put_along_axis(
        zeros, jnp.broadcast_to(idx[None, :, :, None],
                                (k, spec.n_shards, spec.n_sel, spec.block)),
        dw_sel.astype(dtype), axis=2, inplace=False)
    return full.reshape(k, spec.n_shards * spec.n_blocks * spec.block)


def compact_dw(x2, dy2, idx, spec: SelSpec):
    """The paper's compute skip: dW for selected blocks only.

    x2: [M, K], dy2: [M, N] -> [K, n_shards, n_sel, block]
    """
    if kernels_enabled():
        from repro.kernels import ops as kops
        return kops.block_sparse_dw(x2, dy2, idx, spec)
    dy_sel = _gather_blocks(dy2, idx, spec)
    return jnp.einsum("mk,msnb->ksnb", x2, dy_sel,
                      preferred_element_type=jnp.float32)


def _smm_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    k, n = w.shape[-2], w.shape[-1]
    dx = jnp.matmul(dy, jnp.swapaxes(w, -1, -2))
    x2 = x.reshape(-1, k)
    dy2 = dy.reshape(-1, n)
    dw_sel = compact_dw(x2, dy2, idx, spec)
    dw = _scatter_blocks(dw_sel, idx, spec, k, w.dtype)
    return dx.astype(x.dtype), dw, None


_smm.defvjp(_smm_fwd, _smm_bwd)


def smm(x, w, sel, name: str):
    """Sparse matmul: `x @ w` with channel-block-sparse dW.

    sel: None (dense backward) or a pair (idx_dict, spec_dict) where
    idx_dict[name] is int32 [n_shards, n_sel] and spec_dict[name] a SelSpec.
    Weights absent from the dicts fall back to dense backward.
    """
    if sel is None:
        return jnp.matmul(x, w)
    idx_dict, spec_dict = sel
    if idx_dict is None or name not in idx_dict:
        return jnp.matmul(x, w)
    if w.ndim == 2:
        return _smm(x, w, idx_dict[name], spec_dict[name])
    return _smm_batched(x, w, idx_dict[name], spec_dict[name])


# batched (expert) variant: x [E, C, K], w [E, K, N]
@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _smm_batched(x, w, idx, spec: SelSpec):
    return jnp.einsum("eck,ekn->ecn", x, w)


def _smmb_fwd(x, w, idx, spec):
    return jnp.einsum("eck,ekn->ecn", x, w), (x, w, idx)


def _smmb_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    e, c, k = x.shape
    n = w.shape[-1]
    dx = jnp.einsum("ecn,ekn->eck", dy, w)
    dyb = dy.reshape(e, c, spec.n_shards, spec.n_blocks, spec.block)
    dy_sel = jnp.take_along_axis(dyb, idx[None, None, :, :, None], axis=3)
    dw_sel = jnp.einsum("eck,ecsnb->eksnb", x, dy_sel,
                        preferred_element_type=jnp.float32)
    zeros = jnp.zeros((e, k, spec.n_shards, spec.n_blocks, spec.block), w.dtype)
    dw = jnp.put_along_axis(
        zeros, jnp.broadcast_to(idx[None, None, :, :, None],
                                (e, k, spec.n_shards, spec.n_sel, spec.block)),
        dw_sel.astype(w.dtype), axis=3, inplace=False).reshape(e, k, n)
    return dx.astype(x.dtype), dw, None


_smm_batched.defvjp(_smmb_fwd, _smmb_bwd)


# ---------------------------------------------------------------------------
# layer-level split (frozen prefix / trainable suffix over scan stacks)
# ---------------------------------------------------------------------------

def split_stack(stack, n_trainable: int):
    """Split stacked layer params [L, ...] into (frozen [L-K], trainable [K])."""
    if n_trainable <= 0:
        return stack, None
    frozen = jax.tree.map(lambda a: a[: a.shape[0] - n_trainable], stack)
    trainable = jax.tree.map(lambda a: a[a.shape[0] - n_trainable:], stack)
    depth = jax.tree.leaves(stack)[0].shape[0]
    if n_trainable >= depth:
        return None, stack
    return frozen, trainable


def merge_stack(frozen, trainable):
    if frozen is None:
        return trainable
    if trainable is None:
        return frozen
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        frozen, trainable)
