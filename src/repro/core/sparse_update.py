"""Dynamic gradient sparse update — the paper's core, as JAX autodiff machinery.

Two mechanisms (paper §III-B):

1. **Layer selection**: only the last-K scan-blocks of the network are
   trainable. Implemented by splitting the stacked layer params into
   (frozen-prefix, trainable-suffix); `jax.grad` w.r.t. the suffix only means
   XLA never materializes backward residuals for the prefix — the paper's
   "discard the corresponding output features" memory saving.

2. **Channel selection**: within trainable layers, each weight's *output
   channel blocks* are selected with ratio r. `smm` (sparse matmul) is a
   drop-in `x @ w` whose custom VJP computes dW **only for the selected
   blocks** (a compact [K, r·N] matmul instead of [K, N]) and scatters into a
   zero buffer. dX is always dense (needed to keep propagating). The block
   granularity (default 128) is the TPU adaptation: MXU-aligned tiles that
   the Pallas kernel (`repro.kernels.masked_dw`) can skip wholesale.

Selection indices are *data* (int32 arrays), so the dynamic phase of
Algorithm 1 re-randomizes them every step without recompilation.

Selection layout: for a weight with output dim N sharded over `n_shards` TP
shards, `idx` has shape [n_shards, n_sel] holding block indices *local to
each shard* — every shard updates the same number of blocks (the paper's
equal-sparsity-per-PE rule, reborn as TP load balance).

Compact-gradient path (`compact_grads=True` in the train step)
--------------------------------------------------------------
The dense-scatter path above still materializes a full-shape dW per weight
(`_scatter_blocks` writes the compact blocks into a [K, N] zero buffer) and
the optimizer then sweeps the whole tensor. The compact path never leaves
the [*, n_shards, n_sel, block] layout:

1. `gather_param_blocks` pulls the selected blocks of each selectable leaf
   into a compact `w_sel` companion tensor; the train step differentiates
   w.r.t. `w_sel` while the full weight enters the forward matmul with its
   gradient stopped.
2. `_smm_compact` / `_smm_batched_compact` compute the identical forward
   `x @ w` but their VJP emits the compact `compact_dw` /
   `compact_dw_batched` result directly as the cotangent of `w_sel` — no
   zero buffer, no full-shape scatter. Under `use_kernels` both are single
   Pallas launches (`kernels.masked_dw` for 2D weights,
   `kernels.batched_dw` for stacked expert weights: one grid over
   experts x shards x selected blocks).
3. `repro.optim.apply_updates_mixed` clips, applies the SGD/momentum/AdamW
   rule on the gathered blocks (gathering the matching optimizer-state
   blocks), and writes the result back with `scatter_param_blocks` (or the
   Pallas `kernels.scatter_blocks` in-place kernel under `use_kernels`).

Equivalence guarantees vs the dense-scatter path:

- SGD (momentum 0, no weight decay): bitwise identical — the dense path's
  update is the identity outside the selection and performs the exact same
  fp32 arithmetic inside it (`gather(scatter(dw_sel)) == dw_sel`, and the
  fp32->param-dtype cast round-trips untouched values).
- momentum / AdamW with a FIXED selection (phase 0/2 of Algorithm 1, or any
  window without reselection): identical, because optimizer state outside
  the selection stays zero in the dense sweep and untouched in the compact
  path.
- Under dynamic reselection the compact path implements the documented
  "stale state frozen" semantics exactly: deselected blocks keep their
  momentum frozen and their weights fixed. The dense sweep instead lets
  stale momentum keep decaying *and moving* deselected weights — an
  artifact of the sweep, not a property of the algorithm.
- `grad_clip > 0` changes the reduction shape of the global-norm sum, so
  equality holds to float-accumulation order (allclose, not bitwise).
- Weight decay in the compact path touches only selected blocks (the
  paper's "2% of weights updated per step" discipline); the dense sweep
  decays every weight.
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

_FLAGS = threading.local()


class SelSpec(NamedTuple):
    """Static (trace-time) description of one weight's channel selection."""
    block: int        # channels per block
    n_shards: int     # TP shards of the out dim
    n_sel: int        # selected blocks per shard
    n_blocks: int     # total blocks per shard


@contextlib.contextmanager
def use_kernels(enabled: bool = True):
    """Route the compact dW computation through the Pallas kernel."""
    prev = getattr(_FLAGS, "kernels", False)
    _FLAGS.kernels = enabled
    try:
        yield
    finally:
        _FLAGS.kernels = prev


def kernels_enabled() -> bool:
    return getattr(_FLAGS, "kernels", False)


@contextlib.contextmanager
def compact_allreduce(enabled: bool = True):
    """Gradient compression (beyond-paper, EXPERIMENTS.md §Perf): force the
    data-parallel reduction of dW onto the COMPACT selected-block tensor.

    A sharding constraint marks dw_sel as replicated across the DP axes, so
    XLA inserts the cross-data all-reduce there — r x the bytes of the
    full-shape gradient. The scatter to full shape then runs on already-
    replicated operands and needs no further collective."""
    prev = getattr(_FLAGS, "cgr", False)
    _FLAGS.cgr = enabled
    try:
        yield
    finally:
        _FLAGS.cgr = prev


def compact_allreduce_enabled() -> bool:
    return getattr(_FLAGS, "cgr", False)


def compress_grads(grads_segments: dict, sel_idx: dict, spec_tree: dict,
                   logical_tree: Optional[dict] = None):
    """Gradient-compression rewrite (used when compact_allreduce is on):

        dw  ->  scatter(constrain(gather(dw, idx)), idx)

    Selected-block gathers of dw equal dw's only nonzero content, so the
    rewrite is exact. The constraint marks the COMPACT tensor replicated
    across the DP axes (while keeping each leaf's natural TP sharding on its
    other dims, from `logical_tree` = param_logical_specs segments), so XLA
    places the cross-data all-reduce there — r x the full-gradient bytes
    (the paper's selected-channels idea applied to the interconnect)."""
    from repro.sharding import constrain

    def leaf(dw, idx, spec: SelSpec, logical):
        k_steps = dw.shape[0]
        lead = dw.shape[:-1]                   # [K(, E), in]
        dwb = dw.reshape(lead + (spec.n_shards, spec.n_blocks, spec.block))
        # idx: [K, n_shards, n_sel] -> broadcast into the gather
        bidx = idx.reshape((k_steps,) + (1,) * (len(lead) - 1)
                           + (spec.n_shards, spec.n_sel, 1))
        bidx = jnp.broadcast_to(bidx, lead + (spec.n_shards, spec.n_sel,
                                              spec.block))
        dw_sel = jnp.take_along_axis(dwb, bidx, axis=len(lead) + 1)
        # keep the leaf's natural TP sharding on its non-out dims; the out
        # dim's TP sharding (if any) rides the n_shards dim.
        if logical is not None and len(logical) == len(dw.shape):
            in_axes = tuple(logical[:-1])
            out_tp = logical[-1] if spec.n_shards > 1 else None
        else:
            in_axes = ("layers",) + (None,) * (len(lead) - 1)
            out_tp = "ff" if spec.n_shards > 1 else None
        dw_sel = constrain(dw_sel, *in_axes, out_tp, None, None)
        zeros = jnp.zeros_like(dwb)
        dw_new = jnp.put_along_axis(zeros, bidx, dw_sel.astype(dw.dtype),
                                    axis=len(lead) + 1, inplace=False)
        return dw_new.reshape(dw.shape)

    def walk(g, i, s, lg):
        if isinstance(s, SelSpec):
            return leaf(g, i, s, lg)
        if isinstance(s, dict):
            return {k: (walk(g[k], i[k], s[k],
                            (lg or {}).get(k) if isinstance(lg, dict) else None)
                        if k in s else g[k])
                    for k in g}
        return g

    out = {}
    for seg, g in grads_segments.items():
        if sel_idx.get(seg) is None or seg not in spec_tree:
            out[seg] = g
            continue
        lg = (logical_tree or {}).get(seg)
        out[seg] = walk(g, sel_idx[seg], spec_tree[seg], lg)
    return out


# ---------------------------------------------------------------------------
# sparse matmul
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _smm(x, w, idx, spec: SelSpec):
    return jnp.matmul(x, w)


def _smm_fwd(x, w, idx, spec: SelSpec):
    return jnp.matmul(x, w), (x, w, idx)


def _gather_blocks(dy2, idx, spec: SelSpec):
    """dy2: [M, N] -> selected blocks [M, n_shards, n_sel, block]."""
    m = dy2.shape[0]
    dyb = dy2.reshape(m, spec.n_shards, spec.n_blocks, spec.block)
    return jnp.take_along_axis(dyb, idx[None, :, :, None], axis=2)


def _scatter_blocks(dw_sel, idx, spec: SelSpec, k: int, dtype):
    """dw_sel: [K, n_shards, n_sel, block] -> full [K, N] with zeros elsewhere."""
    zeros = jnp.zeros((k, spec.n_shards, spec.n_blocks, spec.block), dtype)
    full = jnp.put_along_axis(
        zeros, jnp.broadcast_to(idx[None, :, :, None],
                                (k, spec.n_shards, spec.n_sel, spec.block)),
        dw_sel.astype(dtype), axis=2, inplace=False)
    return full.reshape(k, spec.n_shards * spec.n_blocks * spec.block)


def compact_dw(x2, dy2, idx, spec: SelSpec):
    """The paper's compute skip: dW for selected blocks only.

    x2: [M, K], dy2: [M, N] -> [K, n_shards, n_sel, block]
    """
    if kernels_enabled():
        from repro.kernels import ops as kops
        return kops.block_sparse_dw(x2, dy2, idx, spec)
    if spec.n_sel == spec.n_blocks:
        # full selection: the gather is a pure permutation, so let the einsum
        # consume a reshaped VIEW of dy2 and reorder the (M-times smaller)
        # output instead of materializing a gathered copy of the activations
        dyb = dy2.reshape(dy2.shape[0], spec.n_shards, spec.n_blocks,
                          spec.block)
        dw_all = jnp.einsum("mk,msnb->ksnb", x2, dyb,
                            preferred_element_type=jnp.float32)
        return jnp.take_along_axis(dw_all, idx[None, :, :, None], axis=2)
    dy_sel = _gather_blocks(dy2, idx, spec)
    return jnp.einsum("mk,msnb->ksnb", x2, dy_sel,
                      preferred_element_type=jnp.float32)


def compact_dw_batched(x3, dy3, idx, spec: SelSpec):
    """Expert-batched compute skip: per-expert dW for selected blocks only.

    x3: [E, C, K], dy3: [E, C, N] -> [E, K, n_shards, n_sel, block].
    Under `use_kernels` this is ONE Pallas launch for all experts x shards x
    selected blocks (`kernels.batched_dw`); the jnp fallback below is the
    oracle the kernel is verified against."""
    if kernels_enabled():
        from repro.kernels import ops as kops
        return kops.block_sparse_dw_batched(x3, dy3, idx, spec)
    e, m, _ = x3.shape
    dyb = dy3.reshape(e, m, spec.n_shards, spec.n_blocks, spec.block)
    dy_sel = jnp.take_along_axis(dyb, idx[None, None, :, :, None], axis=3)
    return jnp.einsum("eck,ecsnb->eksnb", x3, dy_sel,
                      preferred_element_type=jnp.float32)


def _smm_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    k, n = w.shape[-2], w.shape[-1]
    dx = jnp.matmul(dy, jnp.swapaxes(w, -1, -2))
    x2 = x.reshape(-1, k)
    dy2 = dy.reshape(-1, n)
    dw_sel = compact_dw(x2, dy2, idx, spec)
    dw = _scatter_blocks(dw_sel, idx, spec, k, w.dtype)
    return dx.astype(x.dtype), dw, None


_smm.defvjp(_smm_fwd, _smm_bwd)


# compact-VJP variant: same forward, but the weight gradient comes out as
# the compact [K, n_shards, n_sel, block] cotangent of `w_sel` (the gathered
# selected blocks) — nothing full-shape is ever scattered. The caller passes
# `w` with its gradient stopped; its (zero) cotangent is DCE'd by XLA.

@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _smm_compact(x, w, w_sel, idx, spec: SelSpec):
    return jnp.matmul(x, w)


def _smm_compact_fwd(x, w, w_sel, idx, spec: SelSpec):
    return jnp.matmul(x, w), (x, w, idx)


def _smm_compact_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    k, n = w.shape[-2], w.shape[-1]
    dx = jnp.matmul(dy, jnp.swapaxes(w, -1, -2))
    dw_sel = compact_dw(x.reshape(-1, k), dy.reshape(-1, n), idx, spec)
    return (dx.astype(x.dtype), jnp.zeros_like(w),
            dw_sel.astype(w.dtype), None)


_smm_compact.defvjp(_smm_compact_fwd, _smm_compact_bwd)


def smm(x, w, sel, name: str):
    """Sparse matmul: `x @ w` with channel-block-sparse dW.

    sel: None (dense backward), a pair (idx_dict, spec_dict), or a triple
    (idx_dict, spec_dict, wsel_dict). idx_dict[name] is int32
    [n_shards, n_sel], spec_dict[name] a SelSpec. With a triple, the VJP is
    COMPACT: the gradient flows to wsel_dict[name] (the gathered selected
    blocks) instead of being scattered into a full-shape dW. Weights absent
    from the dicts fall back to dense backward.
    """
    if sel is None:
        return jnp.matmul(x, w)
    idx_dict, spec_dict = sel[0], sel[1]
    if idx_dict is None or name not in idx_dict:
        return jnp.matmul(x, w)
    idx, spec = idx_dict[name], spec_dict[name]
    wsel_dict = sel[2] if len(sel) > 2 else None
    if wsel_dict is not None and name in wsel_dict:
        if w.ndim == 2:
            return _smm_compact(x, w, wsel_dict[name], idx, spec)
        return _smm_batched_compact(x, w, wsel_dict[name], idx, spec)
    if w.ndim == 2:
        return _smm(x, w, idx, spec)
    return _smm_batched(x, w, idx, spec)


# batched (expert) variant: x [E, C, K], w [E, K, N]
@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _smm_batched(x, w, idx, spec: SelSpec):
    return jnp.einsum("eck,ekn->ecn", x, w)


def _smmb_fwd(x, w, idx, spec):
    return jnp.einsum("eck,ekn->ecn", x, w), (x, w, idx)


def _smmb_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    e, c, k = x.shape
    n = w.shape[-1]
    dx = jnp.einsum("ecn,ekn->eck", dy, w)
    dw_sel = compact_dw_batched(x, dy, idx, spec)
    zeros = jnp.zeros((e, k, spec.n_shards, spec.n_blocks, spec.block), w.dtype)
    dw = jnp.put_along_axis(
        zeros, jnp.broadcast_to(idx[None, None, :, :, None],
                                (e, k, spec.n_shards, spec.n_sel, spec.block)),
        dw_sel.astype(w.dtype), axis=3, inplace=False).reshape(e, k, n)
    return dx.astype(x.dtype), dw, None


_smm_batched.defvjp(_smmb_fwd, _smmb_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _smm_batched_compact(x, w, w_sel, idx, spec: SelSpec):
    return jnp.einsum("eck,ekn->ecn", x, w)


def _smmbc_fwd(x, w, w_sel, idx, spec):
    return jnp.einsum("eck,ekn->ecn", x, w), (x, w, idx)


def _smmbc_bwd(spec: SelSpec, res, dy):
    x, w, idx = res
    dx = jnp.einsum("ecn,ekn->eck", dy, w)
    dw_sel = compact_dw_batched(x, dy, idx, spec)
    return (dx.astype(x.dtype), jnp.zeros_like(w),
            dw_sel.astype(w.dtype), None)


_smm_batched_compact.defvjp(_smmbc_fwd, _smmbc_bwd)


# ---------------------------------------------------------------------------
# compact-path block gather/scatter (params and optimizer state)
# ---------------------------------------------------------------------------

def _block_idx(idx, spec: SelSpec, lead: tuple, k: int):
    """Broadcast [K, n_shards, n_sel] indices into the blocked-leaf layout."""
    bidx = idx.reshape((k,) + (1,) * len(lead)
                       + (spec.n_shards, spec.n_sel, 1))
    return jnp.broadcast_to(
        bidx, (k,) + lead + (spec.n_shards, spec.n_sel, spec.block))


def gather_param_blocks(w, idx, spec: SelSpec):
    """Stacked leaf [K, *lead, N] -> compact [K, *lead, n_shards, n_sel,
    block] of the selected blocks. idx: [K, n_shards, n_sel]."""
    k = w.shape[0]
    lead = w.shape[1:-1]
    wb = w.reshape((k,) + lead + (spec.n_shards, spec.n_blocks, spec.block))
    return jnp.take_along_axis(wb, _block_idx(idx, spec, lead, k),
                               axis=len(lead) + 2)


def scatter_param_blocks(w, vals, idx, spec: SelSpec):
    """Inverse write of gather_param_blocks: overwrite the selected blocks of
    `w` with `vals` (unselected blocks untouched — the operand is the live
    tensor, NOT a zero buffer). Routes to the Pallas in-place kernel under
    `use_kernels`."""
    if kernels_enabled():
        from repro.kernels import ops as kops
        return kops.block_scatter_update(w, vals.astype(w.dtype), idx, spec)
    k = w.shape[0]
    lead = w.shape[1:-1]
    wb = w.reshape((k,) + lead + (spec.n_shards, spec.n_blocks, spec.block))
    out = jnp.put_along_axis(wb, _block_idx(idx, spec, lead, k),
                             vals.astype(w.dtype), axis=len(lead) + 2,
                             inplace=False)
    return out.reshape(w.shape)


def map_selectable(tree, spec_tree, fn):
    """Apply `fn` to every leaf of `tree` that has a SelSpec in `spec_tree`
    (matched positionally); other leaves pass through unchanged. Works on
    the trainable tree: spec_tree is keyed {"segments": {seg: {leaf: ...}}}
    style via plan.spec — pass `{"segments": plan.spec}`-shaped trees."""
    def walk(node, spec):
        if isinstance(spec, SelSpec):
            return fn(node)
        if isinstance(node, dict):
            return {key: (walk(val, spec[key])
                          if isinstance(spec, dict) and key in spec else val)
                    for key, val in node.items()}
        return node
    return walk(tree, spec_tree)


def gather_selected_tree(segments, idx_tree, spec_tree):
    """Compact companion tree for the trainable segments: for each SelSpec
    leaf, the gathered selected blocks; segments without selection map to
    None. segments/idx_tree/spec_tree are keyed by segment name."""
    def walk(stack, idx, spec):
        if isinstance(spec, SelSpec):
            return gather_param_blocks(stack, idx, spec)
        return {key: walk(stack[key], idx[key], spec[key]) for key in spec}

    out = {}
    for seg, spec in spec_tree.items():
        if idx_tree.get(seg) is None or seg not in segments or not spec:
            out[seg] = None
            continue
        out[seg] = walk(segments[seg], idx_tree[seg], spec)
    return out


# ---------------------------------------------------------------------------
# layer-level split (frozen prefix / trainable suffix over scan stacks)
# ---------------------------------------------------------------------------

def split_stack(stack, n_trainable: int):
    """Split stacked layer params [L, ...] into (frozen [L-K], trainable [K])."""
    if n_trainable <= 0:
        return stack, None
    frozen = jax.tree.map(lambda a: a[: a.shape[0] - n_trainable], stack)
    trainable = jax.tree.map(lambda a: a[a.shape[0] - n_trainable:], stack)
    depth = jax.tree.leaves(stack)[0].shape[0]
    if n_trainable >= depth:
        return None, stack
    return frozen, trainable


def merge_stack(frozen, trainable):
    if frozen is None:
        return trainable
    if trainable is None:
        return frozen
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        frozen, trainable)
