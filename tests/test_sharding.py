"""Distribution-layer tests: logical specs, HLO collective parser, and a
multi-device (8 fake CPU devices, subprocess) integration test proving the
sharded MoE/train-step match the single-device reference."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_logical_specs_cover_all_leaves():
    from repro.configs import ARCH_IDS, get_smoke_config
    from repro.models.registry import abstract_params
    from repro.models.specs import param_logical_specs
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        tree = abstract_params(cfg)
        specs = param_logical_specs(cfg)
        t_leaves = jax.tree_util.tree_leaves_with_path(tree)
        for path, leaf in t_leaves:
            node = specs
            for p in path:
                node = node[str(p.key)]
            assert isinstance(node, tuple) and len(node) == leaf.ndim, \
                (arch, path, node, leaf.shape)


def test_resolve_pspec_divisibility_fallback():
    from types import SimpleNamespace
    from repro.launch.specs import resolve_pspec
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding import AxisRules, default_rules
    P = jax.sharding.PartitionSpec

    rules = default_rules(make_debug_mesh(1, 1))
    # 1x1 mesh: every dim divisible, axes applied as-is
    assert resolve_pspec((10, 7), ("batch", "ff"), rules) == \
        P(("data",), ("model",))
    # simulated 2x4 mesh: 7 % 4 != 0 -> the ff dim falls back to None
    big = AxisRules(dict(rules.rules),
                    mesh=SimpleNamespace(shape={"data": 2, "model": 4}),
                    batch_axes=("data",), model_axis="model")
    assert resolve_pspec((10, 7), ("batch", "ff"), big) == P(("data",), None)
    assert resolve_pspec((10, 8), ("batch", "ff"), big) == \
        P(("data",), ("model",))
    # unknown / None logical names resolve to None without error
    assert resolve_pspec((10, 7), (None, "nope"), big) == P(None, None)


def test_seq_sharded_rules_long_context_decode():
    """long_500k (batch=1): `seq_sharded_rules` moves the batch axes onto
    the KV-cache sequence dim, and `rules_for` extends that with the model
    axis for flash-decoding — 256-way sequence sharding on a full pod."""
    from repro.configs import ShapeConfig, get_smoke_config
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.specs import rules_for
    from repro.sharding import seq_sharded_rules

    mesh = make_debug_mesh(1, 1)
    r = seq_sharded_rules(mesh)
    assert r.rules["batch"] is None            # batch=1: nothing to shard
    assert tuple(r.rules["cache_seq"] or ()) == tuple(r.batch_axes)

    cfg = get_smoke_config("llama3-8b")
    long = rules_for(mesh, cfg, ShapeConfig("long_500k", 64, 1, "decode"))
    assert long.rules["batch"] is None
    assert long.rules["kv_heads"] is None      # GQA gather stays local
    assert tuple(long.rules["cache_seq"]) == tuple(r.batch_axes) + ("model",)
    # every other decode shape keeps batch-parallel defaults: sequence
    # shards over the model axis only
    short = rules_for(mesh, cfg, ShapeConfig("decode_32k", 64, 4, "decode"))
    assert tuple(short.rules["batch"] or ()) == tuple(r.batch_axes)
    assert tuple(short.rules["cache_seq"]) == ("model",)


def test_hlo_collective_parser_synthetic():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
      %p = (s32[], f32[8,4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,4]{1,0} get-tuple-element(%p), index=1
      %ag = f32[8,4]{1,0} all-reduce(%x), channel_id=1, to_apply=%add
      ROOT %t = (s32[], f32[8,4]) tuple(%i, %ag)
    }

    %cond (p: (s32[], f32[8,4])) -> pred[] {
      %p = (s32[], f32[8,4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,4]) -> f32[8,4] {
      %a = f32[8,4]{1,0} parameter(0)
      %i0 = s32[] constant(0)
      %init = (s32[], f32[8,4]) tuple(%i0, %a)
      %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%body
      %ag2 = f32[8,4]{1,0} all-gather(%a), channel_id=2, dimensions={0}
      ROOT %out = f32[8,4]{1,0} get-tuple-element(%w), index=1
    }
    """)
    res = collective_bytes(hlo)
    # loop all-reduce wire bytes: 2 * 8*4*4 * 12 trips + one all-gather
    assert res["total"] == 2 * 128 * 12 + 128
    assert res["by_op"]["all-reduce"] == 2 * 128 * 12
    assert res["by_op"]["all-gather"] == 128
    assert res["naive"] == 2 * 128 + 128


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.configs import get_smoke_config, ShapeConfig, SparseUpdateConfig, OptimizerConfig, TrainConfig
from repro.sharding import default_rules, use_rules
from repro.launch.specs import make_train_cell, rules_for
from repro.train import make_train_state, make_train_step
from repro.models import transformer as T

mesh = make_mesh((2, 4), ("data", "model"))

# --- sharded MoE == local MoE -------------------------------------------
cfg = get_smoke_config("deepseek-moe-16b")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_experts=8,
                                                       capacity_factor=8.0))
params = T.init_params(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size)}
loss_local, _ = T.loss_fn(cfg, (params, None), batch)      # no mesh rules

rules = rules_for(mesh, cfg, ShapeConfig("t", 16, 4, "train"))
with use_rules(rules):
    loss_sharded, _ = jax.jit(lambda p, b: T.loss_fn(cfg, (p, None), b))(params, batch)
ok_moe = abs(float(loss_local) - float(loss_sharded)) < 2e-3

# --- sharded train step == single-device train step ----------------------
cfg2 = get_smoke_config("llama3-8b")
shape = ShapeConfig("t", 16, 4, "train")
tc = TrainConfig(model=cfg2, shape=shape,
                 sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=1, channel_block=8),
                 optimizer=OptimizerConfig(kind="sgd", learning_rate=0.1))
state, plan = make_train_state(tc, jax.random.PRNGKey(0))
step = make_train_step(tc, plan)
batch2 = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, cfg2.vocab_size),
          "labels": jax.random.randint(jax.random.PRNGKey(4), (4, 16), 0, cfg2.vocab_size)}
s_ref, m_ref = jax.jit(step)(state, batch2)

rules2 = rules_for(mesh, cfg2, shape)
with use_rules(rules2):
    s_sh, m_sh = jax.jit(step)(state, batch2)
diff = max(float(jnp.abs(a - b).max()) for a, b in
           zip(jax.tree.leaves(s_ref["params_trainable"]),
               jax.tree.leaves(s_sh["params_trainable"])))
ok_train = abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 2e-3 and diff < 2e-2
print("RESULT", ok_moe, ok_train, float(loss_local), float(loss_sharded), diff)
"""


@pytest.mark.slow
def test_multidevice_equivalence_subprocess():
    """8 fake CPU devices: sharded (2x4 mesh) MoE loss and full DGSU train
    step match the single-device reference numerically."""
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT, SRC],
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1]
    parts = line.split()
    assert parts[1] == "True", f"MoE mismatch: {line}"
    assert parts[2] == "True", f"train-step mismatch: {line}"
