"""Chaos-hardened serving: deterministic fault injection, crash-safe
restart, and graceful degradation.

The contract under test, end to end:

- ``FaultSchedule`` is DETERMINISTIC: same seed + rates -> identical fired
  fault sequence (property test), zero rate -> zero faults, and an engine
  built without a schedule runs the pre-chaos code path with every chaos
  counter at zero.
- Injected faults DEGRADE, never corrupt: under transient step/alloc/
  stream/slow faults the engine's greedy output is TOKEN-IDENTICAL to the
  fault-free run (faults fire before the jitted step and before any pool
  mutation, so retries are idempotent and masked decode rows keep state
  bit-for-bit).
- Poison requests (every step draw fires) exhaust their retry budget and
  are QUARANTINED — dedicated counters, slot freed, neighbors unharmed.
  A hung request is likewise quarantined by the watchdog.
- Admission load-sheds below a free-page watermark without ever dropping
  a request unaccounted.
- A stream callback that raises (injected or real) costs its own stream
  only — the request still completes with the same tokens.
- Crash-safety: after ``InjectedCrash`` mid-run, a restarted engine
  replays journaled in-flight requests to completion with prefix hits
  from the persisted spill tier; the journal tolerates a torn tail.
- Checkpoints carry a checksum footer: bit flips and torn (truncated)
  files raise ``CheckpointCorruptError``; ``CheckpointManager.restore``
  falls back to the latest intact step and only raises when none exists.
"""
import os
import signal
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointManager,
                              load_pytree, save_pytree)
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.runtime import (FaultSchedule, InjectedCrash, InjectedFault,
                           RestartableLoop)
from repro.serve import PagePool, Request, RequestJournal, ServeEngine
from repro.serve.engine import make_shared_prefix_requests
from repro.testing import given, settings, st

PROMPT_LEN = 16
GEN_LEN = 6
PAGE = 4
MAX_LEN = PROMPT_LEN + GEN_LEN
ARCH = "llama3-8b"


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config(ARCH)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _requests(cfg, n=5, seed=3):
    return make_shared_prefix_requests(cfg, n, 2 * PAGE, PROMPT_LEN,
                                       GEN_LEN, seed=seed)


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("page_size", PAGE)
    return ServeEngine(cfg, params, **kw)


def _tokens(stats, status="completed"):
    return {r.rid: list(r.tokens) for r in stats.results.values()
            if r.status == status}


# ---------------------------------------------------------------------------
# FaultSchedule determinism
# ---------------------------------------------------------------------------

def _replay(seed, rate, draws):
    sched = FaultSchedule(seed, fault_rate=rate)
    for kind, site in draws:
        sched.draw(kind, site)
    return sched


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       rate=st.floats(min_value=0.05, max_value=0.95),
       draws=st.lists(st.tuples(
           st.sampled_from(["alloc", "step", "slow", "stream"]),
           st.integers(0, 7)), min_size=1, max_size=64))
def test_fault_schedule_determinism_property(seed, rate, draws):
    """Same seed, same rates, same draw sequence -> identical fired-fault
    sequence; the decision depends only on (seed, kind, counter), never on
    wall time or hash randomization."""
    a = _replay(seed, rate, draws)
    b = _replay(seed, rate, draws)
    assert a.sequence() == b.sequence()
    assert a.faults_injected == b.faults_injected
    assert a.faults_by_kind == b.faults_by_kind
    # the (kind, index) pairs also ignore the site tag: interleaving the
    # SAME per-kind draw order under different sites fires identically
    c = _replay(seed, rate, [(k, s + 1) for k, s in draws])
    assert [(k, i) for k, i, _ in a.sequence()] == \
        [(k, i) for k, i, _ in c.sequence()]


def test_fault_schedule_zero_rate_never_fires():
    sched = FaultSchedule(7, fault_rate=0.0)
    for n in range(500):
        assert sched.draw("step", site=n) is False
    assert sched.faults_injected == 0 and sched.sequence() == []


def test_fault_schedule_seeds_differ():
    """Different seeds must not share a fault sequence (rate high enough
    that both fire plenty, yet at different draw indices)."""
    seqs = set()
    for seed in range(4):
        sched = FaultSchedule(seed, fault_rate=0.3)
        for _ in range(200):
            sched.draw("step")
        seqs.add(tuple(sched.sequence()))
    assert len(seqs) == 4


def test_fault_schedule_poison_and_caps():
    sched = FaultSchedule(0, fault_rate=0.0, poison_rids={11})
    assert sched.draw("step", site=11) is True      # poison always fires
    assert sched.draw("step", site=12) is False
    capped = FaultSchedule(0, fault_rate=1.0, max_faults=3)
    fired = sum(capped.draw("alloc") for _ in range(10))
    assert fired == 3
    crash = FaultSchedule(0, kill_after=2)
    assert not crash.crash_due(1)
    assert crash.crash_due(2) is True
    assert crash.crash_due(3) is False              # fires exactly once


def test_page_pool_alloc_fault_precedes_mutation():
    """An injected alloc failure must leave the pool untouched — the retry
    that follows sees exactly the pre-fault free list."""
    pool = PagePool(4, PAGE, chaos=FaultSchedule(0, rates={"alloc": 1.0}))
    before = pool.free_pages
    with pytest.raises(InjectedFault):
        pool.alloc()
    assert pool.free_pages == before


# ---------------------------------------------------------------------------
# graceful degradation in the engine
# ---------------------------------------------------------------------------

def test_engine_token_parity_under_faults(smoke_model):
    """THE robustness pin: 10% transient faults across every injection
    point may delay requests but must not change a single served token."""
    cfg, params = smoke_model
    ref_stats = _engine(cfg, params).run(_requests(cfg))
    ref = _tokens(ref_stats)
    # the fault-free engine reports every chaos counter at zero
    assert ref_stats.faults_injected == 0 and ref_stats.retries == 0
    assert ref_stats.quarantined == 0 and ref_stats.journal_replays == 0

    chaos = FaultSchedule(0, fault_rate=0.10)
    stats = _engine(cfg, params, chaos=chaos, max_retries=10,
                    retry_backoff_s=0.0005).run(_requests(cfg))
    assert stats.faults_injected > 0, "10% rate never fired — dead wiring"
    assert stats.retries > 0
    assert stats.requests_completed == len(ref)
    assert _tokens(stats) == ref, "injected faults changed served tokens"


def test_stream_fault_and_real_stream_exception_survive(smoke_model):
    """A stream callback that raises — injected or genuinely broken —
    degrades that stream only: the request still completes, with the same
    tokens, and the failures are counted."""
    cfg, params = smoke_model
    ref = _tokens(_engine(cfg, params).run(_requests(cfg, n=2)))

    calls = {"n": 0}

    def broken(rid, tok):
        calls["n"] += 1
        raise ValueError("client went away")

    reqs = _requests(cfg, n=2)
    reqs[0].stream = broken
    chaos = FaultSchedule(0, rates={"stream": 0.5})
    stats = _engine(cfg, params, chaos=chaos).run(reqs)
    assert stats.requests_completed == 2
    assert _tokens(stats) == ref
    assert calls["n"] > 0
    assert stats.stream_errors > 0
    assert stats.faults_injected > 0      # injected stream faults counted


def test_poison_request_quarantined_neighbors_unharmed(smoke_model):
    """Every step draw fires for the poison rid: retries can never save
    it, so the retry budget must quarantine it — and every other request
    completes with fault-free tokens."""
    cfg, params = smoke_model
    ref = _tokens(_engine(cfg, params).run(_requests(cfg)))
    poison = sorted(ref)[1]
    chaos = FaultSchedule(0, poison_rids={poison})
    stats = _engine(cfg, params, chaos=chaos, max_retries=2,
                    retry_backoff_s=0.0005).run(_requests(cfg))
    assert stats.quarantined == 1
    assert stats.retries == 3             # max_retries + the final straw
    assert stats.results[poison].status == "quarantined"
    assert stats.requests_completed == len(ref) - 1
    expected = {rid: t for rid, t in ref.items() if rid != poison}
    assert _tokens(stats) == expected
    # accounting: nothing dropped silently
    assert len(stats.results) == len(ref)


def test_watchdog_quarantines_hung_request(smoke_model):
    """A request making no progress (poison, endless retry budget) trips
    the watchdog instead of spinning forever. The engine is warmed fault-
    free first so compile stalls can't masquerade as hangs, then the
    watchdog is armed for the chaos run."""
    cfg, params = smoke_model
    eng = _engine(cfg, params, max_retries=10 ** 6,
                  retry_backoff_s=0.001, retry_backoff_cap_s=0.002)
    eng.run(_requests(cfg, n=2, seed=9))  # compile both step shapes
    reqs = _requests(cfg, n=3)
    poison = reqs[0].rid
    eng.chaos = FaultSchedule(0, poison_rids={poison})
    eng.watchdog_s = 0.25
    stats = eng.run(reqs)
    assert stats.watchdog_kills >= 1
    assert stats.results[poison].status == "quarantined"
    assert stats.requests_completed == len(reqs) - 1


def test_load_shedding_below_watermark(smoke_model):
    """With a high free-page watermark and a small pool, admission defers
    (sheds) while requests are in flight — and still finishes everything:
    shedding is backpressure, not loss."""
    cfg, params = smoke_model
    need = -(-MAX_LEN // PAGE)            # pages per request, ceil
    stats = _engine(cfg, params, num_slots=3, num_pages=3 * need,
                    prefix_sharing=False,
                    shed_watermark=0.5).run(_requests(cfg, n=6))
    assert stats.sheds > 0, "watermark high enough that shedding must fire"
    assert stats.requests_completed == 6
    ref = _tokens(_engine(cfg, params, num_slots=3, num_pages=3 * need,
                          prefix_sharing=False).run(_requests(cfg, n=6)))
    assert _tokens(stats) == ref


# ---------------------------------------------------------------------------
# crash-safe restart: journal + persisted prefix tier
# ---------------------------------------------------------------------------

def test_crash_journal_replay_with_prefix_hits(smoke_model, tmp_path):
    """Kill the engine after 1 completion; the restarted engine must
    replay every journaled in-flight request to completion, token-
    identical, with prefix hits > 0 from the persisted spill tier."""
    cfg, params = smoke_model
    jpath = str(tmp_path / "journal.jsonl")
    ppath = str(tmp_path / "spill")
    ref = _tokens(_engine(cfg, params).run(_requests(cfg)))

    eng = _engine(cfg, params, chaos=FaultSchedule(0, kill_after=1),
                  journal=jpath, prefix_persist=ppath)
    with pytest.raises(InjectedCrash):
        eng.run(_requests(cfg))
    eng._journal.close()

    eng2 = _engine(cfg, params, journal=jpath, prefix_persist=ppath)
    pending = eng2.recover_requests()
    assert pending, "in-flight requests were admitted before the crash"
    assert all(r.rid in ref for r in pending)
    stats = eng2.run(pending)
    assert stats.requests_completed == len(pending)
    assert stats.journal_replays == len(pending)
    assert stats.prefix_hit_tokens > 0, "restart should be warm, not cold"
    for rid, toks in _tokens(stats).items():
        assert toks == ref[rid]
    # replayed requests were journaled done: a second restart is clean
    eng2._journal.close()
    eng3 = _engine(cfg, params, journal=jpath, prefix_persist=ppath)
    assert eng3.recover_requests() == []


def test_journal_torn_tail_tolerated(tmp_path):
    """A crash mid-append leaves a torn final line; replay must skip it
    (counted + warned) without losing the intact records before it."""
    jpath = str(tmp_path / "j.jsonl")
    j = RequestJournal(jpath)
    j.admit(Request(1, 4, tokens=np.arange(6, dtype=np.int32)))
    j.admit(Request(2, 4, tokens=np.arange(6, dtype=np.int32)))
    j.done(1, "completed")
    j.close()
    with open(jpath, "ab") as f:          # torn tail: half a record
        f.write(b'{"v": {"e": "done", "rid": 2')
    j2 = RequestJournal(jpath)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        pending = j2.pending_requests()
    assert [r.rid for r in pending] == [2]
    assert j2.torn_lines_skipped == 1
    assert pending[0].max_new_tokens == 4
    np.testing.assert_array_equal(pending[0].tokens, np.arange(6))


# ---------------------------------------------------------------------------
# checkpoint integrity: checksum footer, torn writes, fallback restore
# ---------------------------------------------------------------------------

def test_checkpoint_checksum_detects_bitflip(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_pytree(path, {"w": jnp.arange(64.0)})
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path)


def test_restore_falls_back_past_torn_checkpoint(tmp_path):
    """The regression from the satellite list: a truncated latest file is
    detected and restore returns the previous intact step, warning."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.ones((3,))}
    mgr.save(1, tree, {"tag": "old"})
    mgr.save(2, tree, {"tag": "new"})
    p2 = mgr._path(2)
    blob = open(p2, "rb").read()
    open(p2, "wb").write(blob[: len(blob) // 2])   # torn write
    with pytest.warns(UserWarning, match="falling back"):
        loaded, meta = mgr.restore(target=tree)
    assert meta["step"] == 1 and meta["tag"] == "old"
    np.testing.assert_array_equal(np.asarray(loaded["x"]), np.ones(3))
    # every candidate corrupt -> explicit CheckpointCorruptError
    p1 = mgr._path(1)
    blob1 = open(p1, "rb").read()
    open(p1, "wb").write(blob1[:10])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(CheckpointCorruptError):
            mgr.restore(target=tree)


def test_manager_torn_write_injection(tmp_path):
    """chaos 'torn' draws make save() publish a truncated file — restore
    must survive exactly as it would a real torn write."""
    mgr = CheckpointManager(str(tmp_path),
                            chaos=FaultSchedule(0, rates={"torn": 1.0}))
    tree = {"x": jnp.full((2,), 5.0)}
    intact = CheckpointManager(str(tmp_path))
    intact.save(1, tree)
    mgr.save(2, tree)
    assert mgr.torn_writes == 1
    with pytest.warns(UserWarning, match="falling back"):
        loaded, meta = mgr.restore(target=tree)
    assert meta["step"] == 1


# ---------------------------------------------------------------------------
# train-loop satellites: double save, emergency metadata
# ---------------------------------------------------------------------------

class _CountingManager(CheckpointManager):
    def __init__(self, directory):
        super().__init__(directory)
        self.saves = []

    def save(self, step, tree, meta=None):
        self.saves.append(int(step))
        super().save(step, tree, meta)


def test_restartable_loop_no_double_save(tmp_path):
    """total_steps % checkpoint_every == 0 used to save the final step
    twice (periodic + final). Exactly one save per step, final included."""
    mgr = _CountingManager(str(tmp_path))
    state = {"x": jnp.zeros(())}
    loop = RestartableLoop(mgr, state, total_steps=6, checkpoint_every=3)
    loop.run(lambda s, b: ({"x": s["x"] + 1.0}, {}), iter([{}] * 6))
    assert mgr.saves == [3, 6], "final step must be saved exactly once"
    _, meta = mgr.restore(target=state)
    assert meta["step"] == 6 and meta.get("final") is True


def test_emergency_save_records_straggler_state(tmp_path):
    """Preemption mid-run: the emergency checkpoint's metadata carries the
    straggler monitor's flagged steps and rolling median."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        if int(state["step"]) == 7:       # slow step, then SIGTERM
            os.kill(os.getpid(), signal.SIGTERM)
        return ({"x": state["x"] + 1.0, "step": state["step"] + 1},
                {"loss": state["x"]})

    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(factor=2.0, warmup_steps=2)
    for _ in range(6):
        mon.record(0.01)
    mon.record(0.5)                       # pre-flagged straggler
    loop = RestartableLoop(mgr, state, total_steps=100, checkpoint_every=50,
                           straggler=mon)
    result = loop.run(step_fn, iter([{}] * 100))
    assert result["emergency"] is True
    _, meta = mgr.restore(target=state)
    assert meta.get("emergency") is True
    assert meta["stragglers"], "flagged straggler steps missing from meta"
    assert meta["stragglers"][0] == [7, 0.5]
    assert meta["median_step_s"] > 0.0
