"""Unit + property tests for the paper's core (selection / schedule /
sparse matmul / memory / pruning / act-prune)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.configs import SparseUpdateConfig, get_smoke_config
from repro.core.act_prune import block_act_prune, block_sparsity
from repro.core.schedule import coverage_after, maybe_reselect, phase_of
from repro.core.selection import (build_plan, magnitude_selection,
                                  random_selection, selected_fraction)
from repro.core.sparse_update import SelSpec, merge_stack, smm, split_stack


# ---------------------------------------------------------------------------
# sparse matmul (the paper's gradient skip)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6).map(lambda i: i * 4),
    k=st.integers(1, 6).map(lambda i: i * 4),
    n_shards=st.sampled_from([1, 2, 4]),
    n_blocks=st.integers(2, 6),
    block=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_smm_grad_matches_masked_dense(m, k, n_shards, n_blocks, block, seed):
    """Property: smm gradient == dense gradient * channel mask, dx dense."""
    rng = np.random.default_rng(seed)
    n = n_shards * n_blocks * block
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    n_sel = rng.integers(1, n_blocks + 1)
    idx = jnp.asarray(
        np.stack([rng.choice(n_blocks, n_sel, replace=False)
                  for _ in range(n_shards)]), jnp.int32)
    spec = SelSpec(block=block, n_shards=n_shards, n_sel=int(n_sel),
                   n_blocks=n_blocks)
    sel = ({"w": idx}, {"w": spec})

    g = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    gd = jax.grad(lambda w: (jnp.matmul(x, w) ** 2).sum())(w)
    mask = np.zeros((n_shards, n_blocks))
    for s in range(n_shards):
        mask[s, np.asarray(idx[s])] = 1
    mask = np.repeat(mask.reshape(-1), block)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd) * mask,
                               rtol=1e-4, atol=1e-4)
    # forward value unchanged
    np.testing.assert_allclose(np.asarray(smm(x, w, sel, "w")),
                               np.asarray(x @ w), rtol=1e-5, atol=1e-5)
    # dx stays dense-correct
    gx = jax.grad(lambda x: smm(x, w, sel, "w").sum())(x)
    gxd = jax.grad(lambda x: (x @ w).sum())(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gxd),
                               rtol=1e-4, atol=1e-4)


def test_split_merge_roundtrip():
    stack = {"a": jnp.arange(24.0).reshape(6, 4), "b": jnp.ones((6, 2))}
    f, t = split_stack(stack, 2)
    assert t["a"].shape == (2, 4) and f["a"].shape == (4, 4)
    merged = merge_stack(f, t)
    np.testing.assert_array_equal(np.asarray(merged["a"]),
                                  np.asarray(stack["a"]))
    f0, t0 = split_stack(stack, 0)
    assert t0 is None
    fall, tall = split_stack(stack, 6)
    assert fall is None


# ---------------------------------------------------------------------------
# selection plan
# ---------------------------------------------------------------------------

def _plan(ratio=0.25, k=2):
    cfg = get_smoke_config("llama3-8b")
    sp = SparseUpdateConfig(update_ratio=ratio, num_update_layers=k,
                            channel_block=16)
    return cfg, sp, build_plan(cfg, sp)


def test_plan_later_layers_first():
    cfg, sp, plan = _plan()
    assert plan.seg_trainable == {"blocks": 2}
    assert 0 < selected_fraction(plan, cfg) < 1


def test_random_selection_valid_and_unique():
    cfg, sp, plan = _plan()
    idx = random_selection(plan, jax.random.PRNGKey(0))
    for path, leaf in jax.tree_util.tree_leaves_with_path(idx):
        arr = np.asarray(leaf)
        assert arr.min() >= 0
        # unique per (step, shard)
        flat = arr.reshape(-1, arr.shape[-1])
        for row in flat:
            assert len(set(row.tolist())) == len(row)


def test_magnitude_selection_picks_largest_blocks():
    cfg, sp, plan = _plan(ratio=0.25, k=1)
    from repro.models import transformer as T
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    # boost one block of wq in the last layer; it must be selected
    spec = plan.spec["blocks"]["attn"]["wq"]
    wq = params["segments"]["blocks"]["attn"]["wq"]
    boosted = wq.at[-1, :, 3 * spec.block:4 * spec.block].mul(100.0)
    params["segments"]["blocks"]["attn"]["wq"] = boosted
    idx = magnitude_selection(plan, params)
    sel_blocks = np.asarray(idx["blocks"]["attn"]["wq"])[-1, 0]
    assert 3 in sel_blocks.tolist()


def test_phases_and_reselect():
    sp = SparseUpdateConfig(update_ratio=0.5, num_update_layers=1,
                            channel_block=16, phase_fixed_early=5,
                            phase_dynamic=10, phase_fixed_late=5)
    assert phase_of(0, sp) == 0
    assert phase_of(5, sp) == 1
    assert phase_of(14, sp) == 1
    assert phase_of(15, sp) == 2
    cfg = get_smoke_config("llama3-8b")
    plan = build_plan(cfg, sp)
    idx0 = random_selection(plan, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    same = lambda a, b: jax.tree.all(
        jax.tree.map(lambda x, y: bool((x == y).all()), a, b))
    assert same(idx0, maybe_reselect(plan, sp, idx0, jnp.asarray(0), key))
    assert not same(idx0, maybe_reselect(plan, sp, idx0, jnp.asarray(7), key))
    assert same(idx0, maybe_reselect(plan, sp, idx0, jnp.asarray(16), key))


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(0, 200))
def test_coverage_monotone_in_dynamic_steps(steps):
    cfg, sp_, plan = _plan()
    sp = SparseUpdateConfig(update_ratio=0.25, num_update_layers=2,
                            channel_block=16, phase_fixed_early=5,
                            phase_dynamic=1000)
    c1 = coverage_after(plan, sp, steps, None)
    c2 = coverage_after(plan, sp, steps + 10, None)
    assert 0.0 <= c1 <= c2 <= 1.0 + 1e-9


def test_coverage_dynamic_beats_fixed():
    """Paper Fig. 4: dynamic traverses far more parameters over time."""
    cfg, _, plan = _plan(ratio=0.2)
    fixed = SparseUpdateConfig(update_ratio=0.2, num_update_layers=2,
                               channel_block=16, phase_fixed_early=10**6,
                               phase_dynamic=0)
    dyn = SparseUpdateConfig(update_ratio=0.2, num_update_layers=2,
                             channel_block=16, phase_fixed_early=10,
                             phase_dynamic=40)
    c_fixed = coverage_after(plan, fixed, 50, None)
    c_dyn = coverage_after(plan, dyn, 50, None)
    assert c_dyn > 2 * c_fixed


# ---------------------------------------------------------------------------
# memory model / budget solver
# ---------------------------------------------------------------------------

def test_budget_solver_fits_budget():
    from repro.core import memory as mem
    cfg = get_smoke_config("llama3-8b")
    tokens = 8 * 64
    for budget_kb in (64, 256, 1024, 16384):
        sp = SparseUpdateConfig(update_ratio=0.2, channel_block=16,
                                memory_budget_bytes=budget_kb * 1024)
        k = mem.solve_max_layers(cfg, sp, tokens)
        assert k >= 1
        if k > 1:
            assert mem.training_extra_bytes(cfg, sp, k, tokens) <= sp.memory_budget_bytes


def test_sparse_much_smaller_than_dense():
    """The paper's headline: sparse update cuts the training footprint by
    ~10x at the same model (Table II: 2.5MB -> 0.25MB)."""
    from repro.core import memory as mem
    cfg = get_smoke_config("llama3-8b")
    sp = SparseUpdateConfig(update_ratio=0.2, channel_block=16)
    tokens = 8 * 64
    sparse = mem.training_extra_bytes(cfg, sp, 1, tokens)
    dense = mem.dense_training_extra_bytes(cfg, tokens)
    assert sparse * 4 < dense


# ---------------------------------------------------------------------------
# block activation pruning
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 16).map(lambda i: i * 2),
    thr=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_act_prune_properties(rows, cols, thr, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    y = block_act_prune(x, thr, 2)
    yb = np.asarray(y).reshape(rows, cols // 2, 2)
    xb = np.asarray(x).reshape(rows, cols // 2, 2)
    blk_max = np.abs(xb).max(-1)
    # pruned blocks exactly zero; kept blocks untouched
    assert (yb[blk_max < thr] == 0).all()
    np.testing.assert_array_equal(yb[blk_max >= thr], xb[blk_max >= thr])
    # idempotent
    np.testing.assert_array_equal(np.asarray(block_act_prune(y, thr, 2)),
                                  np.asarray(y))


def test_act_prune_sparsity_monotone():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 0.3,
                    jnp.float32)
    s = [float(block_sparsity(x, t, 2)) for t in (0.05, 0.15, 0.5, 1.5)]
    assert s == sorted(s)
    assert s[-1] > 0.9


# ---------------------------------------------------------------------------
# pruning (CNN path)
# ---------------------------------------------------------------------------

def test_pruning_pipeline_sparsity_and_consistency():
    from repro.configs.mobilenetv2_cifar import smoke_config
    from repro.core import pruning
    from repro.models import mobilenet_v2 as MN
    cfg = smoke_config()
    params = MN.init_params(cfg, jax.random.PRNGKey(0))
    pruned, report = pruning.full_prune(params, cfg, channel_target=0.4,
                                        unstructured_rate=0.5)
    assert 0.3 < report["conv_sparsity"] < 0.99
    # dependency consistency: a pruned hidden channel is zero across the group
    masks = pruning.channel_prune_masks(params, cfg, 0.4)
    blk = pruned["b1"]
    keep = np.asarray(masks["b1"])
    dead = np.where(~keep)[0]
    if len(dead):
        assert np.all(np.asarray(blk["dw"]["w"])[..., dead] == 0)
        assert np.all(np.asarray(blk["project"]["w"])[:, :, dead, :] == 0)
        if "expand" in blk:
            assert np.all(np.asarray(blk["expand"]["w"])[..., dead] == 0)
    # forward still finite
    imgs = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, cfg.img_size, cfg.img_size, 3)), jnp.float32)
    logits = MN.forward(cfg, (pruned, None), imgs)
    assert bool(jnp.isfinite(logits).all())


def test_pattern_prune_keeps_4_entries():
    from repro.core.pruning import pattern_prune_kernel
    w = jnp.asarray(np.random.default_rng(0).normal(size=(3, 3, 4, 8)),
                    jnp.float32)
    mask = np.asarray(pattern_prune_kernel(w))
    per_filter = mask.reshape(9, -1).sum(0)
    assert (per_filter == 4).all()


def test_kd_loss_zero_when_equal():
    from repro.core.distill import kd_loss
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 10)),
                         jnp.float32)
    assert abs(float(kd_loss(logits, logits))) < 1e-5
