"""ServeStats unit/denominator regressions (PR-9 satellite bugfixes).

1. `train_wave_ms_per_token` owns the seconds->milliseconds conversion:
   the former `wave_s_per_token` left the *1e3 to each call site, and one
   missed conversion under-reported wave cost by 1000x.
2. `snapshot_hit_rate` denominates by STATE-FAMILY lookups only: llama3
   (attention family) traffic never asks for snapshots, so dividing by all
   prefix lookups diluted the rate toward zero on mixed fleets.
"""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve import ServeEngine
from repro.serve.engine import ServeStats, make_shared_prefix_requests

PAGE = 4


def _stats(**over):
    base = dict(requests_completed=0, requests_cancelled=0, tokens_out=0,
                tokens_cancelled=0, wall_s=0.0, tok_per_s=0.0,
                latency_p50_s=0.0, latency_p95_s=0.0, refills=0,
                prefill_chunks=0, prefix_hit_tokens=0, prefix_lookup_tokens=0,
                pages_total=0, pages_peak=0, cow_splits=0, results={})
    base.update(over)
    return ServeStats(**base)


def test_train_wave_ms_per_token_unit():
    # 2 seconds of wave time over 1000 tokens = 2 ms/token, NOT 0.002
    s = _stats(train_wave_s=2.0, tokens_out=1000)
    assert s.train_wave_ms_per_token == pytest.approx(2.0)
    # the seconds-named property is gone so no call site can double-convert
    assert not hasattr(s, "wave_s_per_token")
    assert _stats().train_wave_ms_per_token == 0.0


def test_snapshot_hit_rate_unit():
    # 3 snapshot hits over 4 state-family lookups; the 20 attention-family
    # lookups in the same window must not dilute the rate
    s = _stats(prefix_lookups=24, state_lookups=4, snapshot_hits=3)
    assert s.snapshot_hit_rate == pytest.approx(0.75)


def _run(arch, seed=3):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, num_slots=2, max_len=20,
                         page_size=PAGE, num_pages=16)
    return engine.run(make_shared_prefix_requests(
        cfg, 6, prefix_len=12, prompt_len=14, gen_len=5, seed=seed))


def test_snapshot_hit_rate_mixed_llama3_jamba_workload():
    sj = _run("jamba-1.5-large-398b")       # hybrid state family
    sl = _run("llama3-8b")                  # attention family
    # jamba: every admission asks for state; later ones hit snapshots
    assert sj.state_lookups > 0 and sj.snapshot_hits > 0
    assert sj.snapshot_hit_rate == pytest.approx(
        sj.snapshot_hits / sj.state_lookups)
    # llama3 performs prefix lookups but never state lookups
    assert sl.prefix_lookups > 0 and sl.state_lookups == 0
    assert sl.snapshot_hits == 0
    # mixed-fleet aggregate: the state-family denominator keeps the rate
    # undiluted; the old all-lookups denominator dragged it down
    hits = sj.snapshot_hits + sl.snapshot_hits
    fixed = hits / max(1, sj.state_lookups + sl.state_lookups)
    diluted = hits / max(1, sj.prefix_lookups + sl.prefix_lookups)
    assert fixed == pytest.approx(sj.snapshot_hit_rate)
    assert diluted < fixed
