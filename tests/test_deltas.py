"""Per-user parameter-delta layer tests.

Three contracts:

1. **Store discipline** (`serve/deltas.py::DeltaStore`): property tests in
   the style of tests/test_paging.py — no leak/double-free across randomized
   admit/release/evict/put sequences, LRU never evicts a pinned entry, and
   capacity is a hard bound (exhaustion raises, never silently grows).
2. **Decode parity**: the gather-add personalized decode
   (`models/common.delta_matmul_add` riding the jitted `paged_step`) is
   token-identical to an oracle that dense-scatters the same delta into a
   copied base model — for ≥2 cache families, and across a mid-stream delta
   update delivered by another request of the same user. The personalized
   engine keeps the non-personalized trace count (2 compiles of the step).
3. **Online training**: the serve-engine train wave keeps the pinned
   2-launch-per-selectable-leaf property of the compact path, measurably
   reduces per-user loss over a seeded workload, and never writes the
   shared base params (bitwise). Plus checkpoint roundtrip of the store.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (OptimizerConfig, SparseUpdateConfig,
                           get_smoke_config)
from repro.models import decoding as D
from repro.models import transformer as T
from repro.serve import (DeltaStore, PersonalizationConfig, Request,
                         ServeEngine)
from repro.testing import given, settings, st

PROMPT_LEN = 12
GEN_LEN = 6
PAGE = 4


def _p13n(lr=0.05, **kw):
    return PersonalizationConfig(
        sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="sgd", learning_rate=lr),
        train_tokens=8, **kw)


def _engine(arch, num_slots, max_len, **kw):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, num_slots=num_slots, max_len=max_len,
                            page_size=PAGE, **kw)


def _oracle_decode(cfg, params, toks, gen_len, max_len):
    """Contiguous batch=1 greedy ground truth (no serve/paging code)."""
    logits, cache = D.prefill(cfg, params,
                              {"tokens": jnp.asarray(toks)[None]},
                              pad_to=max_len)
    ref = [int(jnp.argmax(logits, -1)[0])]
    for t in range(len(toks), len(toks) + gen_len - 1):
        db = {"tokens": jnp.asarray([[ref[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        ref.append(int(jnp.argmax(logits, -1)[0]))
    return ref


def _personalized_params(eng, user):
    """Dense oracle weights: scatter the user's current delta into a copy of
    the base model (the representation personalized decode must never
    materialize)."""
    from repro.core.delta import apply_delta_tree
    from repro.train.steps import merge_params
    entry = eng._deltas.peek(user)
    segs = apply_delta_tree(eng._trainable["segments"],
                            jax.tree.map(jnp.asarray, entry.vals),
                            jax.tree.map(jnp.asarray, entry.idx),
                            eng._plan.spec)
    trainable = dict(eng._trainable)
    trainable["segments"] = segs
    return merge_params(eng._frozen, trainable)


# ---------------------------------------------------------------------------
# store discipline (jax-free: opaque dict entries)
# ---------------------------------------------------------------------------

def _store(capacity):
    return DeltaStore(capacity, make_entry=lambda u: {"user": u},
                      nbytes=lambda e: 8)


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5)),
                    min_size=1, max_size=120),
       capacity=st.integers(1, 4))
def test_delta_store_random_ops(ops, capacity):
    """Model-based: a held-pins dict tracks every admit/release; after every
    op the store's refcounts must match it exactly, pinned users must stay
    resident, and residency must never exceed capacity."""
    store = _store(capacity)
    held: dict[int, int] = {}
    for op, user in ops:
        if op == 0:          # admit: pin, LRU-evicting or raising when full
            full_of_pins = (user not in store and len(store) == store.capacity
                            and all(store.ref(u) > 0 for u in store.users()))
            if full_of_pins:
                with pytest.raises(RuntimeError, match="exhausted"):
                    store.admit(user)
            else:
                entry = store.admit(user)
                assert entry["user"] == user
                held[user] = held.get(user, 0) + 1
        elif op == 1:        # release: below zero is a double-free
            if held.get(user, 0) > 0:
                store.release(user)
                held[user] -= 1
            else:
                with pytest.raises(RuntimeError, match="double-free"):
                    store.release(user)
        elif op == 2:        # explicit eviction: only unpinned entries go
            evicted = store.evict_lru()
            if evicted is not None:
                assert held.get(evicted, 0) == 0
                assert evicted not in store
        else:                # writeback only targets resident users
            if user in store:
                store.put(user, {"user": user, "ver": 1})
            else:
                with pytest.raises(KeyError):
                    store.put(user, {"user": user})
        store.check()
        assert len(store) <= store.capacity
        for u in store.users():
            assert store.ref(u) == held.get(u, 0)
        for u, pins in held.items():
            if pins > 0:
                assert u in store, f"pinned user {u} was evicted"
    # drain: every pin releases cleanly, then the store empties fully
    for u, pins in held.items():
        for _ in range(pins):
            store.release(u)
    while store.evict_lru() is not None:
        store.check()
    assert len(store) == 0


def test_delta_store_lru_respects_pins_and_order():
    store = _store(2)
    store.admit("a")
    store.admit("b")
    with pytest.raises(RuntimeError, match="exhausted"):
        store.admit("c")               # both pinned: hard bound
    store.release("a")
    store.admit("c")                   # evicts "a" (only unpinned entry)
    assert "a" not in store and "b" in store and "c" in store
    assert store.evictions == 1
    store.release("b")
    store.release("c")
    store.get("b")                     # LRU-touch: "c" now least recent
    store.admit("d")
    assert "c" not in store and "b" in store
    store.check()


def test_delta_store_double_free_raises():
    store = _store(2)
    store.admit(1)
    store.release(1)
    with pytest.raises(RuntimeError, match="double-free"):
        store.release(1)


# ---------------------------------------------------------------------------
# gather-add vs dense scatter (unit level)
# ---------------------------------------------------------------------------

def test_delta_matmul_add_matches_dense_scatter():
    """x @ w + gather-add(x, delta) == x @ (w + scatter(delta)) per batch
    row, with rows selecting different blocks."""
    from repro.models.common import delta_matmul_add
    rng = np.random.default_rng(0)
    b, s, d_in = 3, 5, 16
    n_shards, n_blocks, block, n_sel = 2, 4, 8, 2
    n = n_shards * n_blocks * block
    x = rng.normal(size=(b, s, d_in)).astype(np.float32)
    w = rng.normal(size=(d_in, n)).astype(np.float32)
    idx = rng.integers(0, n_blocks, size=(b, n_shards, n_sel)).astype(np.int32)
    val = rng.normal(size=(b, d_in, n_shards, n_sel, block)).astype(np.float32)

    y = jnp.asarray(x) @ jnp.asarray(w)
    delta = {"idx": {"wq": jnp.asarray(idx)}, "val": {"wq": jnp.asarray(val)}}
    out = delta_matmul_add(y, jnp.asarray(x), delta, "wq")

    for i in range(b):
        dw = np.zeros((d_in, n), np.float32)
        for h in range(n_shards):
            for j in range(n_sel):
                c0 = (h * n_blocks + int(idx[i, h, j])) * block
                dw[:, c0:c0 + block] += val[i, :, h, j]
        ref = x[i] @ (w + dw)
        np.testing.assert_allclose(np.asarray(out[i]), ref,
                                   rtol=1e-5, atol=1e-5)
    # an absent leaf name is an exact no-op (shared trace for plain rows)
    assert delta_matmul_add(y, jnp.asarray(x), delta, "wo") is y


def test_delta_matmul_add_zero_rows_exact_noop():
    """Zero delta rows reproduce y bitwise through the f32 roundtrip — the
    guarantee that lets plain requests share the personalized trace."""
    from repro.models.common import delta_matmul_add
    rng = np.random.default_rng(1)
    b, s, d_in, n_shards, n_sel, block = 2, 3, 8, 1, 1, 8
    n = 2 * block
    y = jnp.asarray(rng.normal(size=(b, s, n)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(size=(b, s, d_in)), jnp.bfloat16)
    delta = {"idx": {"wq": jnp.zeros((b, n_shards, n_sel), jnp.int32)},
             "val": {"wq": jnp.zeros((b, d_in, n_shards, n_sel, block),
                                     jnp.float32)}}
    out = delta_matmul_add(y, x, delta, "wq")
    assert out.dtype == y.dtype
    assert jnp.array_equal(out, y)


# ---------------------------------------------------------------------------
# engine parity vs dense-scatter oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ("llama3-8b", "gemma3-4b"))
def test_personalized_decode_matches_dense_oracle(arch):
    """Zero-delta personalized decode == base model; post-wave personalized
    decode == oracle with the delta dense-scattered into copied weights."""
    max_len = PROMPT_LEN + GEN_LEN
    cfg, eng = _engine(arch, 1, max_len, personalization=_p13n())
    rng = np.random.default_rng(7)
    t1 = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    t2 = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)

    r1 = eng.run([Request(0, GEN_LEN, tokens=t1, user=9)]).results[0]
    assert r1.tokens == _oracle_decode(cfg, eng.params, t1, GEN_LEN, max_len), \
        f"{arch}: zero-delta personalized decode diverged from base model"

    pers = _personalized_params(eng, 9)   # delta after request 1's wave
    assert any(np.abs(np.asarray(l)).max() > 0
               for l in jax.tree.leaves(eng._deltas.peek(9).vals)), \
        "train wave left the delta at zero — nothing to test"
    r2 = eng.run([Request(1, GEN_LEN, tokens=t2, user=9)]).results[1]
    assert r2.tokens == _oracle_decode(cfg, pers, t2, GEN_LEN, max_len), \
        f"{arch}: personalized decode diverged from dense-scatter oracle"


def _switch_oracle(cfg, base, pers, toks, gen, max_len, k):
    """Greedy oracle whose first k tokens are sampled under `base` and the
    rest under `pers`, on one continuously-growing cache — the exact
    semantics of a mid-stream delta update (old K/V entries stay)."""
    logits, cache = D.prefill(cfg, base, {"tokens": jnp.asarray(toks)[None]},
                              pad_to=max_len)
    out = [int(jnp.argmax(logits, -1)[0])]
    for t in range(len(toks), len(toks) + gen - 1):
        params = base if len(out) < k else pers
        db = {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_midstream_delta_update_parity():
    """Two same-user requests in flight: the short one completes, its train
    wave advances the user's delta, and the long one's remaining tokens must
    switch to the new delta mid-stream. The post-wave delta is reproduced by
    an identical fresh engine serving the short request alone (greedy
    serving never splits the engine PRNG, so the first wave key matches)."""
    gen_a, gen_b = 8, 2
    max_len = PROMPT_LEN + gen_a
    cfg, eng = _engine("llama3-8b", 2, max_len,
                       personalization=_p13n(lr=1.0))
    rng = np.random.default_rng(21)
    ta = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    tb = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    stats = eng.run([Request(0, gen_a, tokens=ta, user=5),
                     Request(1, gen_b, tokens=tb, user=5)])
    assert stats.train_waves == 2
    served = stats.results[0].tokens

    # delta after B's wave, from a fresh identical engine serving B alone
    cfg2, eng2 = _engine("llama3-8b", 2, max_len,
                         personalization=_p13n(lr=1.0))
    eng2.run([Request(1, gen_b, tokens=tb, user=5)])
    pers1 = _personalized_params(eng2, 5)

    base = eng.params
    candidates = {k: _switch_oracle(cfg, base, pers1, ta, gen_a, max_len, k)
                  for k in range(1, gen_a + 1)}
    matched = [k for k, c in candidates.items() if c == served]
    assert matched, "request A matches no base->delta switch point"
    assert any(k < gen_a for k in matched), (
        "request A decoded entirely under the pre-update delta — the "
        "mid-stream refresh never reached its slot")
    assert served != candidates[gen_a], (
        "update invisible in tokens (raise the test lr?)")


def test_personalized_trace_count_unchanged():
    """Personalized + plain requests share the jitted step: 2 compiles
    total (prefill shape + decode shape), same as a non-personalized
    engine — user deltas are batch-row data, never trace constants."""
    max_len = PROMPT_LEN + GEN_LEN
    cfg, eng = _engine("llama3-8b", 2, max_len, personalization=_p13n())
    rng = np.random.default_rng(3)
    reqs = [Request(i, GEN_LEN,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        PROMPT_LEN).astype(np.int32),
                    user=(7 if i % 2 == 0 else None))
            for i in range(3)]
    stats = eng.run(reqs)
    assert stats.requests_completed == 3
    assert eng._step._cache_size() == 2, (
        "personalization changed the paged_step trace count")


# ---------------------------------------------------------------------------
# online train wave: launch cert + loss reduction + base immutability
# ---------------------------------------------------------------------------

def test_online_wave_kernel_launch_count():
    """The wave keeps the compact path's pinned launch count: exactly 2
    Pallas launch sites per selectable leaf of the decode-pruned plan (fused
    dW + fused optimizer); the delta materialize/extract gathers add none."""
    from repro.core import build_plan, random_selection
    from repro.core.delta import decode_delta_spec, zeros_delta_tree
    from repro.core.sparse_update import SelSpec
    from repro.launch.hlo_analysis import kernel_launch_count
    from repro.train.steps import make_online_wave, split_params

    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    sparse = SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                channel_block=8)
    opt = OptimizerConfig(kind="sgd", learning_rate=0.05)
    plan = build_plan(cfg, sparse, 0)
    frozen, trainable = split_params(params, plan)
    spec = decode_delta_spec(plan, trainable["segments"])
    plan = dataclasses.replace(plan, spec=spec)

    wave = make_online_wave(cfg, sparse, opt, plan, wave_tokens=8,
                            kernels=True)
    idx = random_selection(plan, jax.random.PRNGKey(1))
    vals = zeros_delta_tree(trainable["segments"], idx, spec, xp=jnp)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "labels": jnp.zeros((1, 8), jnp.int32)}
    jaxpr = jax.make_jaxpr(wave)(trainable, frozen, vals, idx, batch,
                                 jax.random.PRNGKey(2))
    leaves = [l for s in spec.values()
              for l in jax.tree_util.tree_leaves(
                  s, is_leaf=lambda x: isinstance(x, SelSpec))]
    assert leaves, "decode-pruned plan has no selectable leaves"
    assert kernel_launch_count(jaxpr) == 2 * len(leaves)


def test_online_personalization_reduces_user_loss():
    """Seeded served workload, one user: wave losses (measured BEFORE each
    update) must end below where they started, while the shared base params
    stay bitwise identical."""
    max_len = PROMPT_LEN + GEN_LEN
    cfg, eng = _engine("llama3-8b", 1, max_len, personalization=_p13n())
    before = [np.asarray(l).copy() for l in jax.tree.leaves(eng.params)]
    toks = np.random.default_rng(5).integers(
        0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    stats = eng.run([Request(i, GEN_LEN, tokens=toks, user=1)
                     for i in range(4)])
    losses = [loss for user, loss in stats.wave_losses]
    assert len(losses) == 4 and stats.train_waves == 4
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"user loss did not fall: {losses}"
    after = jax.tree.leaves(eng.params)
    for a, b in zip(before, after):
        assert np.array_equal(a, np.asarray(b)), \
            "online personalization wrote the shared base params"


def test_user_selection_stable_across_eviction():
    """A user's channel selection is derived from the user id, so an entry
    evicted and later re-admitted selects the SAME blocks (old checkpoints
    of that user's delta stay meaningful)."""
    cfg, eng = _engine("llama3-8b", 1, PROMPT_LEN + GEN_LEN,
                       personalization=_p13n(store_capacity=1))
    e1, e2 = eng._make_delta_entry(42), eng._make_delta_entry(42)
    for a, b in zip(jax.tree.leaves(e1.idx), jax.tree.leaves(e2.idx)):
        assert np.array_equal(a, b)
    e3 = eng._make_delta_entry(43)
    assert any(not np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(e1.idx), jax.tree.leaves(e3.idx)))


# ---------------------------------------------------------------------------
# checkpoint roundtrip
# ---------------------------------------------------------------------------

def test_delta_store_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_delta_store, save_delta_store
    from repro.core.delta import DeltaState

    def make(user):
        rng = np.random.default_rng(hash(user) % (2 ** 31))
        return DeltaState(
            idx={"layers": {"attn": {"wq": rng.integers(
                0, 4, (2, 2, 2)).astype(np.int32)}}},
            vals={"layers": {"attn": {"wq": rng.normal(
                size=(2, 16, 2, 2, 8)).astype(np.float32)}}})

    store = DeltaStore(4, make)
    for u in (1, 2, 3):
        store.admit(u)
        store.release(u)
    store.get(1)                       # LRU order now [2, 3, 1]
    path = str(tmp_path / "deltas.ckpt")
    save_delta_store(path, store, meta={"tag": "t"})

    store2 = DeltaStore(4, make)
    meta = restore_delta_store(path, store2)
    assert meta["tag"] == "t"
    assert store2.users() == store.users() == [2, 3, 1]
    for u in (1, 2, 3):
        a, b = store.peek(u), store2.peek(u)
        for x, y in zip(jax.tree.leaves(a.to_tree()),
                        jax.tree.leaves(b.to_tree())):
            assert x.dtype == y.dtype and np.array_equal(x, y)
        assert store2.ref(u) == 0      # restored entries come back unpinned
    store2.check()
