"""Sharded paged serving: `paged_step` through shard_map over the model axis,
with every layer tensor-parallel (col/row-parallel linears, vocab-parallel
embed + logits) and per-user deltas riding the sharded step.

Fast tier-1 tests pin the flash-decoding split softmax to the monolithic
softmax (1e-6), single-device engine parity with flash_decode forced on,
the full shard_map plumbing on a one-shard mesh (personalized requests
included), and the rejection paths (indivisible KV heads, rules without a
mesh). The replication audit (multi-device lane) proves the sharded step
performs ZERO full-size matmuls on policy-sharded leaves. The slow
subprocess test forces 8 host CPU devices and proves 2-/4-way sharded
decode token-identical to the single-device engine — and, for llama3, to
the contiguous batch=1 oracle — for all four cache families plus a
deepseek-style MoE, including chunked prefill crossing page boundaries, a
radix prefix hit whose rehydration lands on the sharded pool, and a
personalized (delta) request mix with online train waves.
"""
import dataclasses
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as SH
from repro.configs import get_smoke_config
from repro.launch.mesh import make_serve_mesh
from repro.models import decoding as D
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve import ServeEngine
from repro.serve.engine import make_shared_prefix_requests
from repro.sharding import default_rules

PAGE = 4


def _tokens(stats):
    return {r.rid: list(r.tokens) for r in stats.results.values()}


# ---------------------------------------------------------------------------
# flash-decoding split softmax == monolithic softmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,hd,L_kv,tile", [
    (2, 1, 4, 2, 8, 13, 4),     # batched decode row, L not a tile multiple
    (1, 4, 8, 4, 8, 16, 4),     # prefill chunk, exact tiling
    (3, 4, 4, 4, 16, 7, 8),     # MHA, single ragged tile
    (2, 1, 8, 2, 8, 21, 4),     # deep GQA grouping
])
def test_flash_decode_matches_monolithic_softmax(b, s, hq, hkv, hd, L_kv,
                                                 tile):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, L_kv, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, L_kv, hkv, hd)), jnp.float32)
    mask = jnp.asarray(rng.random((b, s, L_kv)) > 0.4)
    mask = mask.at[:, :, 0].set(True)      # every query has >= 1 valid key
    mono = L._grouped_scores(q, k, v, mask)
    split = L._grouped_scores_split(q, k, v, mask, tile)
    assert float(jnp.abs(mono - split).max()) < 1e-6


def test_flash_decode_engine_token_parity():
    """flash_decode=True on the single-device engine must serve the same
    tokens as the default monolithic softmax."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    reqs = lambda: make_shared_prefix_requests(cfg, 4, 8, 11, 4, seed=3)
    ref = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE)
    fd = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE,
                     flash_decode=True)
    assert _tokens(ref.run(reqs())) == _tokens(fd.run(reqs()))
    # the DEFAULT single-device path stays bit-identical: one trace for
    # chunked prefill + one for batched decode
    assert ref._step._cache_size() == 2


# ---------------------------------------------------------------------------
# shard_map plumbing on a one-shard mesh (runs on a single CPU device)
# ---------------------------------------------------------------------------

def test_sharded_engine_one_shard_parity():
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    reqs = lambda: make_shared_prefix_requests(cfg, 4, 8, 11, 4, seed=3)
    ref = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE)
    rules = default_rules(make_serve_mesh(1))
    sh = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE,
                     rules=rules)
    s_ref, s_sh = ref.run(reqs()), sh.run(reqs())
    assert _tokens(s_ref) == _tokens(s_sh)
    assert s_sh.mesh_shards == 1
    assert s_sh.pool_shard_bytes > 0


# ---------------------------------------------------------------------------
# rejection paths (satellite 3)
# ---------------------------------------------------------------------------

def test_model_axis_size_without_mesh_raises():
    rules = SH.AxisRules({"heads": "model"}, mesh=None, model_axis="model")
    with SH.use_rules(rules):
        with pytest.raises(ValueError, match="no mesh"):
            SH.model_axis_size()
    # no rules installed, or rules without a model axis: still 1
    assert SH.model_axis_size() == 1
    with SH.use_rules(SH.AxisRules({}, mesh=None, model_axis=None)):
        assert SH.model_axis_size() == 1


def _fake_rules(n):
    mesh = types.SimpleNamespace(shape={"model": n},
                                 axis_names=("data", "model"))
    return SH.AxisRules({"paged_pool": "model"}, mesh=mesh,
                        model_axis="model")


def test_pool_sharding_rejects_indivisible_kv_heads():
    cfg = get_smoke_config("llama3-8b")         # smoke: Hq=4, Hkv=2
    with pytest.raises(ValueError, match="num_kv_heads"):
        D.validate_pool_sharding(cfg, _fake_rules(3))
    # the engine rejects at construction, before any device work
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="num_kv_heads"):
        ServeEngine(cfg, params, num_slots=1, max_len=8, page_size=PAGE,
                    rules=_fake_rules(3))
    # Hkv divides but Hq does not
    with pytest.raises(ValueError, match="num_heads"):
        D.validate_pool_sharding(
            dataclasses.replace(cfg, num_heads=6, num_kv_heads=4),
            _fake_rules(4))
    # divisible head counts validate to the mesh width
    assert D.validate_pool_sharding(cfg, _fake_rules(2)) == 2
    # a state-only arch has no pools to shard: any width passes through
    assert D.validate_pool_sharding(get_smoke_config("rwkv6-3b"),
                                    _fake_rules(3)) == 3


def test_engine_rejects_rules_without_mesh():
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    bad = SH.AxisRules({"heads": "model"}, mesh=None, model_axis="model")
    with pytest.raises(ValueError, match="mesh"):
        ServeEngine(cfg, params, num_slots=1, max_len=8, page_size=PAGE,
                    rules=bad)


def _p13n():
    from repro.configs import OptimizerConfig, SparseUpdateConfig
    from repro.serve import PersonalizationConfig
    return PersonalizationConfig(
        sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="sgd", learning_rate=0.05),
        train_tokens=8)


def test_sharded_engine_personalized_one_shard_parity():
    """The mesh x personalization exclusion is lifted: a sharded engine
    serves a mixed plain/personalized workload token-identical to the
    single-device personalized engine, with the same 2 jitted-step traces
    (prefill shape + decode shape — deltas ride one fixed structure)."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def reqs():
        rs = make_shared_prefix_requests(cfg, 4, 8, 11, 4, seed=3)
        for r in rs[::2]:
            r.user = 7      # same user twice: a train wave lands mid-run
        return rs

    ref = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE,
                      personalization=_p13n())
    sh = ServeEngine(cfg, params, num_slots=2, max_len=16, page_size=PAGE,
                     rules=default_rules(make_serve_mesh(1)),
                     personalization=_p13n())
    s_ref, s_sh = ref.run(reqs()), sh.run(reqs())
    assert _tokens(s_ref) == _tokens(s_sh)
    assert s_ref.train_waves == s_sh.train_waves > 0
    assert sh._step._cache_size() == 2
    assert ref._step._cache_size() == 2


# ---------------------------------------------------------------------------
# replication audit: zero full-size matmuls on policy-sharded leaves
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a >= 2-device mesh (multi-device CI lane)")
def test_replication_audit_sharded_step():
    """Every matmul the sharding policy covers (MLP, embed/LM head,
    attention) must consume its LOCAL shard inside the sharded step — the
    single-device step over the same shapes trips the audit, proving the
    detector sees full-size matmuls when they exist."""
    from repro.launch.hlo_analysis import replicated_matmul_leaves
    # d_ff = 96 (not the smoke default 2 * d_model): keeps MLP full shapes
    # from colliding with attention locals, so the forbidden set stays rich
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"), d_ff=96)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rules = default_rules(make_serve_mesh(2))
    step = D.make_sharded_paged_step(cfg, rules, params, page_size=PAGE)
    state, pools = D.init_serve_cache(cfg, 2, 16, 8, PAGE)
    pt = jnp.zeros((2, 4), jnp.int32)
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32),
             "start": jnp.zeros((2,), jnp.int32),
             "active": jnp.ones((2,), bool),
             "length": jnp.ones((2,), jnp.int32)}
    forbidden, allowed = D.sharded_param_shapes(cfg, params, rules)
    # the policy must actually shard the MLP (d_ff divides the mesh here)
    assert (cfg.d_model, cfg.d_ff) in forbidden
    args = (params, batch, state, pools, pt)
    hits = replicated_matmul_leaves(lambda *a: step(*a), args, forbidden)
    assert hits == [], f"full-size matmuls on sharded leaves: {hits}"
    # sensitivity: the replicated (single-device) step over the same full
    # params shows the forbidden shapes the audit exists to catch
    ref_hits = replicated_matmul_leaves(
        lambda p, b, st, pl, t: D.paged_step(cfg, p, b, st, pl, t,
                                             page_size=PAGE),
        args, forbidden)
    assert ref_hits, "audit failed to flag a fully-replicated step"


# ---------------------------------------------------------------------------
# forced 8-device host mesh: 2-/4-way sharded == single-device, all families
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_serve_mesh
from repro.models import decoding as D
from repro.models import transformer as T
from repro.serve import ServeEngine
from repro.serve.engine import make_shared_prefix_requests
from repro.sharding import default_rules

PAGE = 4
PREFIX = 8       # two full pages: snapshots + shared pages on the boundary
PROMPT = 11      # 2 full pages + a 3-row partial: chunks cross boundaries
GEN = 4
MAXLEN = 16

def toks(stats):
    return {r.rid: list(r.tokens) for r in stats.results.values()}

def acct(stats):
    return (stats.pages_peak, stats.cow_splits, stats.prefix_hit_tokens,
            stats.prefill_chunks)

def run_twice(engine, cfg):
    # run 2 re-matches run 1's prefixes: the tree is fresh but the spill
    # tier is warm, so hits REHYDRATE spilled pages into the (sharded) pool
    reqs = lambda: make_shared_prefix_requests(
        cfg, 3, PREFIX, PROMPT, GEN, seed=3)
    return engine.run(reqs()), engine.run(reqs())

def oracle(cfg, params, prompt, gen):
    logits, cache = D.prefill(cfg, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              pad_to=MAXLEN)
    out = [int(jnp.argmax(logits, -1)[0])]
    for t in range(len(prompt), len(prompt) + gen - 1):
        db = {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out

assert jax.device_count() >= 8, jax.device_count()
all_ok = True
for arch in ("llama3-8b", "gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b",
             "deepseek-moe-16b"):
    cfg = get_smoke_config(arch)
    if cfg.num_heads:
        # smoke configs keep Hkv=2; a 4-way mesh needs Hkv % 4 == 0
        cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ref = ServeEngine(cfg, params, num_slots=2, max_len=MAXLEN,
                      page_size=PAGE, num_pages=16)
    r1, r2 = run_twice(ref, cfg)
    ok_trace = ref._step._cache_size() == 2
    # state/hybrid archs truncate prefix matches to page boundaries, so
    # they share only full pages (no COW); positivity is a llama3 claim,
    # cross-shard equality is in acct() for everyone
    ok_cow = arch != "llama3-8b" or r1.cow_splits > 0
    ok_snap = (not ref._need_state) or r1.snapshot_hits > 0
    ok_oracle = True
    if arch == "llama3-8b":
        reqs = make_shared_prefix_requests(cfg, 3, PREFIX, PROMPT, GEN,
                                           seed=3)
        ok_oracle = all(
            r1.results[q.rid].tokens == oracle(cfg, params,
                                               np.asarray(q.tokens), GEN)
            for q in reqs)
    for n in (2, 4):
        rules = default_rules(make_serve_mesh(n))
        eng = ServeEngine(cfg, params, num_slots=2, max_len=MAXLEN,
                          page_size=PAGE, num_pages=16, rules=rules)
        s1, s2 = run_twice(eng, cfg)
        ok_par = toks(s1) == toks(r1) and toks(s2) == toks(r2)
        ok_acct = acct(s1) == acct(r1) and acct(s2) == acct(r2)
        ok_rehy = (not eng.prefix_sharing) or s2.rehydrates > 0
        eng._pool.check()
        # radix tree keeps resident pages across runs; residency must be
        # device-layout independent, i.e. identical to the 1-device engine
        ok_pool = eng._pool.in_use == ref._pool.in_use
        ok_shard = s1.mesh_shards == n and (
            not eng.has_pages or s1.pool_shard_bytes > 0)
        ok = (ok_par and ok_acct and ok_rehy and ok_pool and ok_shard
              and ok_trace and ok_cow and ok_snap and ok_oracle)
        all_ok = all_ok and ok
        print("RESULT", arch, n, int(ok_par), int(ok_acct), int(ok_rehy),
              int(ok_pool), int(ok_shard), int(ok_trace), int(ok_cow),
              int(ok_snap), int(ok_oracle), flush=True)

# --- personalized (delta) request mix on the sharded step -------------------
# decode_delta_spec targets attention/MLP projections, so the mix runs on
# llama3; waves train on the replicated host params, making the resulting
# deltas — and therefore the served tokens — mesh-width independent.
from repro.configs import OptimizerConfig, SparseUpdateConfig
from repro.serve import PersonalizationConfig

def p13n():
    return PersonalizationConfig(
        sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="sgd", learning_rate=0.05),
        train_tokens=8)

def preqs(cfg):
    rs = make_shared_prefix_requests(cfg, 4, PREFIX, PROMPT, GEN, seed=5)
    for r in rs[::2]:
        r.user = 7          # repeat user: a train wave fires mid-run, so
    return rs               # later requests decode through a live delta

cfg = get_smoke_config("llama3-8b")
cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4)
params = T.init_params(cfg, jax.random.PRNGKey(0))
pref = ServeEngine(cfg, params, num_slots=2, max_len=MAXLEN, page_size=PAGE,
                   num_pages=16, personalization=p13n())
p1 = pref.run(preqs(cfg))
assert p1.train_waves > 0, p1.train_waves
for n in (2, 4):
    peng = ServeEngine(cfg, params, num_slots=2, max_len=MAXLEN,
                       page_size=PAGE, num_pages=16,
                       rules=default_rules(make_serve_mesh(n)),
                       personalization=p13n())
    s1 = peng.run(preqs(cfg))
    ok_par = toks(s1) == toks(p1)
    ok_wave = s1.train_waves == p1.train_waves
    ok_trace = peng._step._cache_size() == 2
    ok = ok_par and ok_wave and ok_trace
    all_ok = all_ok and ok
    print("PRESULT", n, int(ok_par), int(ok_wave), int(ok_trace), flush=True)
print("ALLOK", int(all_ok), flush=True)
"""

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


@pytest.mark.slow
def test_sharded_parity_forced_multidevice():
    """8 forced host CPU devices: 2-/4-way sharded decode token-identical
    to the single-device engine for every cache family plus a deepseek-style
    MoE, with page accounting device-layout independent, run-2 prefix hits
    rehydrating onto the sharded pool, and a personalized request mix whose
    train waves and served tokens are mesh-width independent."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT, SRC],
                          capture_output=True, text=True, timeout=1500,
                          env=env)
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    lines = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")]
    assert len(lines) == 10, proc.stdout      # 5 archs x 2 mesh widths
    plines = [l for l in proc.stdout.splitlines() if l.startswith("PRESULT")]
    assert len(plines) == 2, proc.stdout      # personalized mix, n in (2, 4)
    assert "ALLOK 1" in proc.stdout, proc.stdout
