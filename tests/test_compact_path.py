"""Compact-gradient training path: equivalence against the dense-scatter
path (SGD / momentum / AdamW, dense + MoE archs, Pallas kernel routing),
the no-full-gradient-scatter HLO guarantee, and checkpoint round-tripping
of the (unchanged, full-shape) train state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (OptimizerConfig, ShapeConfig, SparseUpdateConfig,
                           TrainConfig, get_smoke_config)
from repro.train import make_train_state, make_train_step


def _tc(arch="llama3-8b", kind="sgd", **opt_kw):
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("t", 16, 4, "train")
    return TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind=kind, learning_rate=0.05, **opt_kw))


def _batch(cfg, seed=3):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (4, 16),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                         (4, 16), 0, cfg.vocab_size)}


def _run(tc, plan, state, batch, compact, steps=3):
    step = jax.jit(make_train_step(tc, plan=plan, compact_grads=compact))
    for _ in range(steps):
        state, metrics = step(state, batch)
    return state, metrics


def _max_diff(a_tree, b_tree):
    return max(float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
               for a, b in zip(jax.tree.leaves(a_tree),
                               jax.tree.leaves(b_tree)))


@pytest.mark.parametrize("kind,opt_kw,tol", [
    ("sgd", {}, 0.0),                       # bitwise (see sparse_update doc)
    ("momentum", {"momentum": 0.9}, 1e-6),
    ("adamw", {}, 1e-6),
])
def test_compact_matches_dense_scatter(kind, opt_kw, tol):
    tc = _tc(kind=kind, **opt_kw)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = _batch(tc.model)
    sd, md = _run(tc, plan, state, batch, compact=False)
    sc, mc = _run(tc, plan, state, batch, compact=True)
    assert float(md["loss"]) == pytest.approx(float(mc["loss"]), abs=1e-5)
    diff = _max_diff(sd["params_trainable"], sc["params_trainable"])
    assert diff <= tol, diff
    # optimizer state also matches (stale state frozen == zero in fixed phase)
    if sd["opt"]:
        assert _max_diff(sd["opt"], sc["opt"]) <= tol


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "rwkv6-3b"])
def test_compact_matches_dense_other_archs(arch):
    tc = _tc(arch=arch, kind="momentum", momentum=0.9)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = _batch(tc.model)
    sd, _ = _run(tc, plan, state, batch, compact=False, steps=2)
    sc, _ = _run(tc, plan, state, batch, compact=True, steps=2)
    assert _max_diff(sd["params_trainable"], sc["params_trainable"]) <= 1e-6


def test_compact_hlo_has_no_full_gradient_scatter():
    """The acceptance check: the jitted compact step's lowering contains no
    scatter into a zero-initialized blocked-weight buffer; the dense-scatter
    step contains one per selectable weight."""
    from repro.core.sparse_update import SelSpec
    from repro.launch.hlo_analysis import weight_gradient_scatters
    tc = _tc(kind="momentum", momentum=0.9)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = _batch(tc.model)
    specs = [l for seg in plan.spec.values()
             for l in jax.tree_util.tree_leaves(
                 seg, is_leaf=lambda x: isinstance(x, SelSpec))]
    texts = {}
    for compact in (False, True):
        step = make_train_step(tc, plan, compact_grads=compact)
        texts[compact] = jax.jit(step).lower(state, batch).as_text()
    assert len(weight_gradient_scatters(texts[False], specs)) > 0, \
        "detector lost track of the dense path's gradient scatters"
    offenders = weight_gradient_scatters(texts[True], specs)
    assert offenders == [], offenders


@pytest.mark.parametrize("kind,opt_kw,tol", [
    ("sgd", {}, 1e-5),
    ("momentum", {"momentum": 0.9}, 1e-5),
    # adamw's g/(sqrt(g^2)+eps) normalizer amplifies the dW kernel's
    # accumulation-order differences by O(lr) for near-zero gradient
    # elements — the update direction is sign-like there, so the
    # end-to-end tolerance is looser (the optimizer kernel itself is
    # allclose 1e-6 vs its oracle; see test_kernels)
    ("adamw", {}, 1e-2),
])
def test_compact_with_pallas_kernels(kind, opt_kw, tol):
    """use_kernels routes compact dW + the fused gather/rule/writeback
    optimizer kernel through Pallas (interpret mode on CPU) and stays
    allclose to the jnp compact path — params AND optimizer state."""
    from repro.core.sparse_update import use_kernels
    tc = _tc(kind=kind, **opt_kw)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = _batch(tc.model)
    s_jnp, _ = _run(tc, plan, state, batch, compact=True, steps=1)
    # interpret-mode pallas_call doesn't jit-cache well; run un-jitted
    step = make_train_step(tc, plan, compact_grads=True)
    with use_kernels(True):
        s_k, _ = step(state, batch)
    assert _max_diff(s_jnp["params_trainable"],
                     s_k["params_trainable"]) <= tol
    if s_jnp["opt"]:
        assert _max_diff(s_jnp["opt"], s_k["opt"]) <= tol


def _selectable_leaves(plan):
    from repro.core.sparse_update import SelSpec
    return [l for seg, steps in plan.seg_trainable.items() if steps
            for l in jax.tree_util.tree_leaves(
                plan.spec[seg], is_leaf=lambda x: isinstance(x, SelSpec))]


def test_compact_kernel_launch_count():
    """The fused acceptance check: the lowered compact train step contains a
    CONSTANT number of Pallas launch sites per selectable weight leaf — one
    fused dW (inside the backward scan) plus one fused optimizer update —
    independent of the trainable-layer count K (the PR 1 path grew as
    O(K x n_shards) from its per-shard / per-(K, shard) Python loops)."""
    from repro.core.sparse_update import use_kernels
    from repro.launch.hlo_analysis import kernel_launch_count
    counts, leaves = {}, {}
    for k_layers in (1, 3):
        cfg = get_smoke_config("llama3-8b")
        tc = TrainConfig(
            model=cfg, shape=ShapeConfig("t", 16, 4, "train"),
            sparse=SparseUpdateConfig(update_ratio=0.5,
                                      num_update_layers=k_layers,
                                      channel_block=8),
            optimizer=OptimizerConfig(kind="momentum", momentum=0.9,
                                      learning_rate=0.05))
        state, plan = make_train_state(tc, jax.random.PRNGKey(0))
        step = make_train_step(tc, plan, compact_grads=True)
        with use_kernels(True):
            jaxpr = jax.make_jaxpr(step)(state, _batch(cfg))
        counts[k_layers] = kernel_launch_count(jaxpr)
        leaves[k_layers] = len(_selectable_leaves(plan))
    assert counts[1] == counts[3], counts
    assert counts[3] == 2 * leaves[3], (counts, leaves)


def test_kernel_launch_count_text_mode():
    """Text mode counts Pallas/Mosaic custom-calls in compiled TPU HLO."""
    from repro.launch.hlo_analysis import kernel_launch_count
    hlo = """
      %fusion = f32[8,128] fusion(f32[8,128] %p0)
      %cc.1 = f32[8,128] custom-call(f32[8,128] %p1), custom_call_target="tpu_custom_call"
      %cc.2 = (f32[8,128], f32[8]) custom-call(%p2), custom_call_target="Mosaic"
      %other = f32[4] custom-call(%p3), custom_call_target="Sharding"
    """
    assert kernel_launch_count(hlo) == 2
    assert kernel_launch_count("no kernels here") == 0


def test_compact_dynamic_phase_trains():
    """Dynamic reselection (fresh selection every step) under the compact
    path: selection changes, selected blocks move, loss stays finite."""
    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("t", 16, 4, "train")
    tc = TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=0.3, num_update_layers=2,
                                  channel_block=8, phase_fixed_early=0,
                                  phase_dynamic=100),
        optimizer=OptimizerConfig(kind="sgd", learning_rate=0.05))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(tc, plan, compact_grads=True))
    batch = _batch(cfg)
    s = state
    for _ in range(3):
        prev = s
        s, m = step(s, batch)
        assert np.isfinite(float(m["loss"]))
    changed = _max_diff(prev["params_trainable"], s["params_trainable"])
    assert changed > 0.0
    sel_changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(prev["sel_idx"]),
                        jax.tree.leaves(s["sel_idx"])))
    assert sel_changed, "dynamic phase must re-randomize the selection"


def test_compact_state_checkpoint_roundtrip(tmp_path):
    """The compact step leaves the train-state layout unchanged (full-shape
    fp32 state, same tree); save -> restore -> continue is bit-identical to
    an uninterrupted run."""
    from repro.checkpoint import CheckpointManager
    tc = _tc(kind="momentum", momentum=0.9)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(tc, plan, compact_grads=True))
    batch = _batch(tc.model)

    s, _ = step(state, batch)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, s)
    s_cont, _ = step(s, batch)                      # uninterrupted

    restored, meta = mgr.restore(1, target=s)
    assert meta["step"] == 1
    s_res, _ = step(restored, batch)                # resumed
    assert _max_diff(s_cont["params_trainable"],
                     s_res["params_trainable"]) == 0.0
    if s_cont["opt"]:
        assert _max_diff(s_cont["opt"], s_res["opt"]) == 0.0


# ---------------------------------------------------------------------------
# MoE batched (expert) compact backward: parity vs dense per-expert einsum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernels", [False, True], ids=["jnp", "kernels"])
@pytest.mark.parametrize("n_experts", [2, 4])
@pytest.mark.parametrize("n_sel", [1, 3])
def test_smm_batched_compact_matches_per_expert_dense(n_experts, n_sel,
                                                      kernels):
    """`_smm_batched_compact` (the MoE expert path) must emit per-expert
    compact dW identical to the dense per-expert einsum gathered at the
    selection — including odd n_sel — with zero cotangent on the
    (gradient-stopped) full weight. Under `use_kernels` the backward is the
    single-launch Pallas `batched_dw` kernel and must stay allclose (1e-6)
    to the same oracle."""
    from repro.core.sparse_update import (SelSpec, _smm_batched_compact,
                                          use_kernels)
    spec = SelSpec(block=8, n_shards=2, n_sel=n_sel, n_blocks=4)
    e, c, k = n_experts, 12, 16
    n = spec.n_shards * spec.n_blocks * spec.block
    kx, kw, kc, ki = jax.random.split(jax.random.PRNGKey(42), 4)
    x = jax.random.normal(kx, (e, c, k), jnp.float32)
    w = jax.random.normal(kw, (e, k, n), jnp.float32)
    cot = jax.random.normal(kc, (e, c, n), jnp.float32)
    idx = jnp.sort(jnp.stack([
        jax.random.permutation(jax.random.fold_in(ki, s),
                               spec.n_blocks)[:n_sel]
        for s in range(spec.n_shards)]), axis=1).astype(jnp.int32)
    w_sel = jnp.zeros((e, k, spec.n_shards, n_sel, spec.block), jnp.float32)

    out = _smm_batched_compact(x, w, w_sel, idx, spec)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.einsum("eck,ekn->ecn", x, w)),
                               rtol=1e-5, atol=1e-5)

    def loss(x, w, w_sel):
        return jnp.vdot(_smm_batched_compact(x, w, w_sel, idx, spec), cot)

    with use_kernels(kernels):
        dx, dw, dw_sel = jax.grad(loss, argnums=(0, 1, 2))(x, w, w_sel)
    assert np.all(np.asarray(dw) == 0.0)      # full weight: gradient stopped

    for ei in range(e):                       # dense per-expert oracle
        dw_dense = jnp.einsum("ck,cn->kn", x[ei], cot[ei],
                              preferred_element_type=jnp.float32)
        dwb = dw_dense.reshape(k, spec.n_shards, spec.n_blocks, spec.block)
        expect = jnp.take_along_axis(dwb, idx[None, :, :, None], axis=2)
        np.testing.assert_allclose(np.asarray(dw_sel[ei]),
                                   np.asarray(expect), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(jnp.einsum("ecn,ekn->eck", cot, w)),
        rtol=1e-5, atol=1e-5)


def _moe_tc(n_experts: int, k_layers: int, num_layers: int = 5):
    import dataclasses
    cfg0 = get_smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(
        cfg0, num_layers=num_layers,
        moe=dataclasses.replace(cfg0.moe, num_experts=n_experts, top_k=2))
    return TrainConfig(
        model=cfg, shape=ShapeConfig("t", 16, 4, "train"),
        sparse=SparseUpdateConfig(update_ratio=0.5,
                                  num_update_layers=k_layers,
                                  channel_block=8),
        optimizer=OptimizerConfig(kind="momentum", momentum=0.9,
                                  learning_rate=0.05))


def test_moe_compact_with_pallas_kernels():
    """The MoE arch under use_kernels: the expert leaves' backward runs the
    batched-dW kernel and the fused optimizer updates the stacked expert
    leaf — params AND optimizer state stay allclose to the jnp compact
    path."""
    from repro.core.sparse_update import use_kernels
    tc = _moe_tc(n_experts=4, k_layers=2, num_layers=3)
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    batch = _batch(tc.model)
    step = make_train_step(tc, plan, compact_grads=True)
    s_jnp, m_jnp = step(state, batch)
    with use_kernels(True):
        s_k, m_k = step(state, batch)
    assert float(m_jnp["loss"]) == pytest.approx(float(m_k["loss"]), abs=1e-5)
    assert _max_diff(s_jnp["params_trainable"],
                     s_k["params_trainable"]) <= 1e-5
    if s_jnp["opt"]:
        assert _max_diff(s_jnp["opt"], s_k["opt"]) <= 1e-5


def test_moe_compact_kernel_launch_count():
    """The MoE acceptance check: the lowered compact train step has a
    CONSTANT number of Pallas launch sites per expert-sharded leaf — one
    batched dW in the backward scan plus one fused optimizer — asserted
    EQUAL across (n_experts, K) in {(2, 1), (4, 3)} (num_layers is held at
    5 so both K values stay inside the same MoE segment and the selectable
    leaf set is identical)."""
    from repro.core.sparse_update import use_kernels
    from repro.launch.hlo_analysis import (kernel_launch_breakdown,
                                           kernel_launch_count)
    counts, leaves, breakdowns = {}, {}, {}
    for n_experts, k_layers in ((2, 1), (4, 3)):
        tc = _moe_tc(n_experts, k_layers)
        state, plan = make_train_state(tc, jax.random.PRNGKey(0))
        step = make_train_step(tc, plan, compact_grads=True)
        with use_kernels(True):
            jaxpr = jax.make_jaxpr(step)(state, _batch(tc.model))
        key = (n_experts, k_layers)
        counts[key] = kernel_launch_count(jaxpr)
        leaves[key] = len(_selectable_leaves(plan))
        breakdowns[key] = kernel_launch_breakdown(jaxpr)
    (k1, k2) = counts
    assert counts[k1] == counts[k2], counts
    assert leaves[k1] == leaves[k2], leaves
    assert counts[k2] == 2 * leaves[k2], (counts, leaves)
    # per-kernel budget: exactly one batched-dW site per expert leaf
    # (w_gate/w_up/w_down), independent of n_experts and K
    for key, bd in breakdowns.items():
        assert bd.get("batched_dw._kernel", 0) == 3, (key, bd)
    assert breakdowns[k1] == breakdowns[k2], breakdowns
