import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — unit tests run on 1 device.
# Multi-device tests spawn subprocesses with their own flags.
