"""Checkpointing + fault-tolerance runtime: roundtrip, retention, atomic
publish, resume determinism, preemption, stragglers, elastic reshard."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.runtime import PreemptionHandler, RestartableLoop, StragglerMonitor


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(["float32", "int32", "bfloat16"]))
def test_pytree_roundtrip_property(tmp_path_factory, seed, dtype):
    tmp = tmp_path_factory.mktemp("ckpt")
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, size=rng.integers(1, 4)))
    leaf = jnp.asarray(rng.normal(size=shape) * 10, jnp.dtype(dtype))
    tree = {"a": {"b": leaf, "c": jnp.arange(3)}, "d": leaf.T.copy()}
    path = str(tmp / f"x_{seed}.ckpt")
    save_pytree(path, tree, {"k": 1})
    loaded, meta = load_pytree(path, target=tree)
    assert meta["k"] == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.ones((3,))}
    for step in (10, 20, 30, 40):
        mgr.save(step, tree)
    assert mgr.all_steps() == [30, 40]
    assert mgr.latest_step() == 40
    loaded, meta = mgr.restore(target=tree)
    assert meta["step"] == 40


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError):
        mgr.restore(target={"x": jnp.ones((4,))})


def test_elastic_reshard_on_load(tmp_path):
    """Checkpoint written without a mesh restores with explicit shardings
    (single-device here; the sharding tree plumbing is what's exercised)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(1, 1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    path = str(tmp_path / "x.ckpt")
    save_pytree(path, tree)
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    loaded, _ = load_pytree(path, target=tree, shardings=sh)
    assert loaded["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(loaded["w"]),
                                  np.asarray(tree["w"]))


def test_training_resume_is_deterministic(tmp_path):
    """Crash/restart invariance: 10 straight steps == 5 steps + restore +
    5 steps (state AND data stream resume identically)."""
    from repro.configs import (OptimizerConfig, ShapeConfig,
                               SparseUpdateConfig, TrainConfig,
                               get_smoke_config)
    from repro.data import lm_batches
    from repro.train import make_train_state, make_train_step

    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("t", 16, 4, "train")
    tc = TrainConfig(model=cfg, shape=shape,
                     sparse=SparseUpdateConfig(update_ratio=0.5,
                                               num_update_layers=1,
                                               channel_block=16),
                     optimizer=OptimizerConfig(kind="momentum", momentum=0.9,
                                               learning_rate=0.05))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(tc, plan))

    def run(state, start, n):
        data = lm_batches(4, 16, cfg.vocab_size, seed=7, start_step=start)
        for i, b in zip(range(n), data):
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        return state, m

    sA, mA = run(state, 0, 10)

    s5, _ = run(state, 0, 5)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, s5)
    s5r, meta = mgr.restore(target=s5)
    sB, mB = run(s5r, 5, 5)
    assert float(mA["loss"]) == pytest.approx(float(mB["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(sA["params_trainable"]),
                    jax.tree.leaves(sB["params_trainable"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=2.0, warmup_steps=3)
    for _ in range(10):
        mon.record(0.10)
    assert not mon.flagged
    assert mon.record(0.35) is True
    assert len(mon.flagged) == 1


def test_preemption_triggers_emergency_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        if int(state["step"]) == 2:   # simulate SIGTERM mid-training
            os.kill(os.getpid(), signal.SIGTERM)
        return ({"x": state["x"] + 1.0, "step": state["step"] + 1},
                {"loss": state["x"]})

    loop = RestartableLoop(mgr, state, total_steps=100, checkpoint_every=50)
    result = loop.run(step_fn, iter([{}] * 100))
    assert result["emergency"] is True
    assert result["step"] == 3
    assert mgr.latest_step() == 3
    loaded, meta = mgr.restore(target=state)
    assert meta.get("emergency") is True
    assert float(loaded["x"]) == 3.0


def test_restartable_loop_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.zeros(())}
    step_fn = lambda s, b: ({"x": s["x"] + 1.0}, {})
    loop = RestartableLoop(mgr, state, total_steps=7, checkpoint_every=3)
    loop.run(step_fn, iter([{}] * 7))
    assert mgr.latest_step() == 7
    # new loop resumes from 7 and does nothing more
    loop2 = RestartableLoop(mgr, state, total_steps=7, checkpoint_every=3)
    start = loop2.resume()
    assert start == 7
    assert float(loop2.state["x"]) == 7.0
