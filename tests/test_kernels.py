"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode executes the kernel body exactly as staged for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.kernels import ref
from repro.kernels.block_act_prune import block_act_prune_kernel
from repro.kernels.masked_dw import block_sparse_dw_kernel


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,block,tm,tk", [
    (64, 32, 64, 16, 32, 16),
    (128, 64, 96, 32, 64, 64),
    (256, 128, 128, 128, 128, 128),   # MXU-aligned full-config shape
    (32, 16, 48, 8, 32, 16),
])
def test_block_sparse_dw_sweep(dtype, m, k, n, block, tm, tk):
    rng = np.random.default_rng(m * 7 + n)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    dy = jnp.asarray(rng.normal(size=(m, n)), dtype)
    n_blocks = n // block
    n_sel = max(1, n_blocks // 2)
    idx = jnp.asarray(rng.choice(n_blocks, n_sel, replace=False), jnp.int32)
    out = block_sparse_dw_kernel(x, dy, idx, block=block, tm=tm, tk=tk,
                                 interpret=True)
    want = ref.block_sparse_dw_ref(x, dy, idx, block)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@settings(max_examples=15, deadline=None)
@given(
    m_t=st.integers(1, 4), k_t=st.integers(1, 4),
    nb=st.integers(2, 6), blk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_sparse_dw_property(m_t, k_t, nb, blk, seed):
    rng = np.random.default_rng(seed)
    m, k = 32 * m_t, 16 * k_t
    n = nb * blk
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    n_sel = int(rng.integers(1, nb + 1))
    idx = jnp.asarray(rng.choice(nb, n_sel, replace=False), jnp.int32)
    out = block_sparse_dw_kernel(x, dy, idx, block=blk, tm=32, tk=16,
                                 interpret=True)
    want = ref.block_sparse_dw_ref(x, dy, idx, blk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,nb,blk,n_sel,tr", [
    (32, 8, 8, 3, 32),
    (64, 4, 16, 2, 32),
    (128, 16, 128, 8, 128),   # MXU-aligned full-config shape
    (256, 6, 8, 6, 256),      # full selection: every block overwritten
])
def test_block_scatter_update_sweep(dtype, r, nb, blk, n_sel, tr):
    from repro.kernels.scatter_blocks import block_scatter_update_kernel
    rng = np.random.default_rng(r * 3 + nb)
    w = jnp.asarray(rng.normal(size=(r, nb * blk)), dtype)
    upd = jnp.asarray(rng.normal(size=(r, n_sel, blk)), dtype)
    idx = jnp.asarray(rng.choice(nb, n_sel, replace=False), jnp.int32)
    out = block_scatter_update_kernel(w, upd, idx, tr=tr, interpret=True)
    want = ref.block_scatter_update_ref(w, upd, idx, blk)
    # pure write routing — must be exact in any dtype
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


@given(
    r_t=st.integers(1, 4), nb=st.integers(2, 8),
    blk=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_block_scatter_update_property(r_t, nb, blk, seed):
    from repro.kernels.scatter_blocks import block_scatter_update_kernel
    rng = np.random.default_rng(seed)
    r = 16 * r_t
    w = jnp.asarray(rng.normal(size=(r, nb * blk)), jnp.float32)
    n_sel = int(rng.integers(1, nb + 1))
    idx = jnp.asarray(rng.choice(nb, n_sel, replace=False), jnp.int32)
    upd = jnp.asarray(rng.normal(size=(r, n_sel, blk)), jnp.float32)
    out = block_scatter_update_kernel(w, upd, idx, tr=16, interpret=True)
    want = ref.block_scatter_update_ref(w, upd, idx, blk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,c,tr,tc,blk,thr", [
    (64, 64, 32, 32, 2, 0.15),
    (128, 256, 64, 128, 2, 0.15),
    (32, 128, 32, 64, 4, 0.3),
    (256, 512, 256, 512, 2, 0.05),
])
def test_block_act_prune_sweep(dtype, r, c, tr, tc, blk, thr):
    rng = np.random.default_rng(r + c)
    x = jnp.asarray(rng.normal(size=(r, c)) * 0.3, dtype)
    out = block_act_prune_kernel(x, threshold=thr, block=blk, tr=tr, tc=tc,
                                 interpret=True)
    want = ref.block_act_prune_ref(x, thr, blk)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


def test_kernel_integrates_with_smm_grad():
    """kernels-enabled smm backward == jnp smm backward == masked dense."""
    from repro.core.sparse_update import SelSpec, smm, use_kernels
    rng = np.random.default_rng(0)
    k, n = 32, 64
    x = jnp.asarray(rng.normal(size=(4, 16, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    spec = SelSpec(block=16, n_shards=1, n_sel=2, n_blocks=4)
    idx = jnp.asarray([[0, 3]], jnp.int32)
    sel = ({"w": idx}, {"w": spec})
    g_jnp = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    with use_kernels(True):
        g_kern = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g_kern), np.asarray(g_jnp),
                               rtol=1e-4, atol=1e-4)


def test_ops_block_act_prune_nd():
    from repro.kernels import ops
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 64)) * 0.2,
                    jnp.float32)
    out = ops.block_act_prune(x, threshold=0.15, block=2)
    want = ref.block_act_prune_ref(x, 0.15, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("bh,t,d", [(3, 128, 16), (2, 64, 32), (1, 256, 8)])
def test_wkv6_chunk_kernel(chunk, bh, t, d):
    """Chunked WKV6 kernel == sequential recurrence oracle."""
    from repro.kernels.wkv6_chunk import wkv6_chunk_kernel
    rng = np.random.default_rng(bh * t + d)
    r = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.asarray(rng.normal(size=(bh, t, d)) - 1.0,
                                     jnp.float32)))
    u = jnp.asarray(rng.normal(size=(d,)) * 0.3, jnp.float32)
    out = wkv6_chunk_kernel(r, k, v, w, u, chunk=min(chunk, t),
                            interpret=True)
    want = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
