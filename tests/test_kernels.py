"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode executes the kernel body exactly as staged for TPU).

The fused single-launch kernels (PR 3) are swept across n_shards in
{1, 2, 4}, stacked K in {1, 3}, odd n_sel, and bf16/f32 params; the fused
optimizer is bitwise vs the un-fused oracle for SGD and allclose for
momentum/AdamW.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.kernels import ref
from repro.kernels.batched_dw import (batched_dw_kernel,
                                      batched_dw_pipelined_kernel)
from repro.kernels.block_act_prune import block_act_prune_kernel
from repro.kernels.fused_block_opt import fused_block_opt_kernel
from repro.kernels.masked_dw import (block_sparse_dw_kernel,
                                     block_sparse_dw_pipelined_kernel)
from repro.kernels.scatter_blocks import block_scatter_update_kernel


def _sel_idx(rng, lead_shape, n_blocks, n_sel):
    """Random no-duplicate selection of shape [*lead_shape, n_sel]."""
    flat = [rng.choice(n_blocks, n_sel, replace=False)
            for _ in range(int(np.prod(lead_shape)))]
    return jnp.asarray(np.stack(flat).reshape(lead_shape + (n_sel,)),
                       jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("m,k,nb,block,n_sel,tm,tk", [
    (64, 32, 4, 16, 3, 32, 16),       # odd n_sel
    (128, 64, 3, 32, 2, 64, 64),
    (256, 128, 2, 128, 1, 128, 128),  # MXU-aligned full-config block
    (32, 16, 6, 8, 5, 32, 16),        # odd n_sel
])
def test_block_sparse_dw_sweep(dtype, n_shards, m, k, nb, block, n_sel, tm, tk):
    rng = np.random.default_rng(m * 7 + nb * n_shards)
    n = n_shards * nb * block
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    dy = jnp.asarray(rng.normal(size=(m, n)), dtype)
    idx = _sel_idx(rng, (n_shards,), nb, n_sel)
    out = block_sparse_dw_kernel(x, dy, idx, block=block, tm=tm, tk=tk,
                                 interpret=True)
    assert out.shape == (k, n_shards, n_sel, block)
    want = ref.block_sparse_dw_ref(x, dy, idx, block)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@settings(max_examples=15, deadline=None)
@given(
    m_t=st.integers(1, 4), k_t=st.integers(1, 4),
    s=st.sampled_from([1, 2, 4]), nb=st.integers(2, 6),
    blk=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1),
)
def test_block_sparse_dw_property(m_t, k_t, s, nb, blk, seed):
    rng = np.random.default_rng(seed)
    m, k = 32 * m_t, 16 * k_t
    n = s * nb * blk
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    n_sel = int(rng.integers(1, nb + 1))
    idx = _sel_idx(rng, (s,), nb, n_sel)
    out = block_sparse_dw_kernel(x, dy, idx, block=blk, tm=32, tk=16,
                                 interpret=True)
    want = ref.block_sparse_dw_ref(x, dy, idx, blk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant", [block_sparse_dw_pipelined_kernel],
                         ids=["pipelined"])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("m,k,nb,block,n_sel,tm,tk", [
    (64, 32, 4, 16, 3, 32, 16),       # odd n_sel
    (128, 64, 3, 32, 2, 64, 64),
    (32, 16, 6, 8, 5, 32, 16),        # odd n_sel
])
def test_block_sparse_dw_pipelined_sweep(variant, n_shards, m, k, nb, block,
                                         n_sel, tm, tk):
    """The emit_pipeline double-buffered variant must match the grid
    kernel's oracle exactly as the grid kernel does."""
    rng = np.random.default_rng(m * 5 + nb * n_shards)
    n = n_shards * nb * block
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    idx = _sel_idx(rng, (n_shards,), nb, n_sel)
    out = variant(x, dy, idx, block=block, tm=tm, tk=tk, interpret=True)
    want = ref.block_sparse_dw_ref(x, dy, idx, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("variant", [batched_dw_kernel,
                                     batched_dw_pipelined_kernel],
                         ids=["grid", "pipelined"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_experts", [2, 4])
@pytest.mark.parametrize("n_shards", [1, 2])
@pytest.mark.parametrize("c,k,nb,block,n_sel,tm,tk", [
    (32, 32, 4, 16, 3, 32, 16),       # odd n_sel
    (16, 16, 6, 8, 5, 16, 16),        # odd n_sel
    (64, 32, 3, 32, 2, 32, 32),
])
def test_batched_dw_sweep(variant, dtype, n_experts, n_shards, c, k, nb,
                          block, n_sel, tm, tk):
    """Expert-batched compact dW (one launch over experts x shards x
    selected blocks) vs the per-expert jnp einsum oracle, grid AND
    pipelined variants."""
    rng = np.random.default_rng(c * 3 + nb * n_shards + n_experts)
    n = n_shards * nb * block
    x = jnp.asarray(rng.normal(size=(n_experts, c, k)), dtype)
    dy = jnp.asarray(rng.normal(size=(n_experts, c, n)), dtype)
    idx = _sel_idx(rng, (n_shards,), nb, n_sel)
    out = variant(x, dy, idx, block=block, tm=tm, tk=tk, interpret=True)
    assert out.shape == (n_experts, k, n_shards, n_sel, block)
    want = ref.batched_dw_ref(x, dy, idx, block)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_batched_dw_deselected_expert_blocks_frozen():
    """End-to-end freeze guarantee for the expert leaf: with the batched-dW
    kernel in the backward and the fused optimizer applied on the stacked
    expert leaf, the DESELECTED blocks of every expert — weights and
    optimizer state — come back bitwise untouched."""
    from repro.core.sparse_update import SelSpec, smm, use_kernels
    rng = np.random.default_rng(7)
    e, c, k, s, nb, blk, n_sel = 3, 16, 16, 2, 4, 8, 1
    n = s * nb * blk
    spec = SelSpec(block=blk, n_shards=s, n_sel=n_sel, n_blocks=nb)
    x = jnp.asarray(rng.normal(size=(e, c, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, k, n)), jnp.float32)
    idx = _sel_idx(rng, (s,), nb, n_sel)
    sel = ({"w": idx}, {"w": spec})
    with use_kernels(True):
        dw = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    sel_mask = np.zeros((e, k, s, nb, blk), bool)
    for si in range(s):
        sel_mask[:, :, si, np.asarray(idx)[si], :] = True
    sel_mask = sel_mask.reshape(e, k, n)
    dw_np = np.asarray(dw)
    assert (dw_np[~sel_mask] == 0.0).all(), \
        "deselected expert blocks received gradient"
    assert np.abs(dw_np[sel_mask]).max() > 0

    # the fused optimizer on the stacked expert leaf ([K, E, d, N] flattened
    # lead) leaves the deselected blocks of params AND state bitwise frozen
    k_steps = 2
    w_leaf = jnp.asarray(rng.normal(size=(k_steps, e, k, n)), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(k_steps, e, k, n)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(k_steps, e, k, s, n_sel, blk)),
                    jnp.float32)
    idx2 = _sel_idx(rng, (k_steps, s), nb, n_sel)
    w3 = w_leaf.reshape(k_steps, e * k, n)
    mu3 = mu.reshape(k_steps, e * k, n)
    g5 = g.reshape(k_steps, e * k, s, n_sel, blk)
    w2, mu2, _ = fused_block_opt_kernel(
        w3, g5, idx2, jnp.float32(0.1), jnp.float32(1.0), mu3,
        kind="momentum", momentum=0.9, tr=16, interpret=True)
    mask2 = np.zeros((k_steps, e * k, s, nb, blk), bool)
    for kk in range(k_steps):
        for si in range(s):
            mask2[kk, :, si, np.asarray(idx2)[kk, si], :] = True
    mask2 = mask2.reshape(k_steps, e * k, n)
    for before, after in ((w3, w2), (mu3, mu2)):
        b, a = np.asarray(before), np.asarray(after)
        np.testing.assert_array_equal(a[~mask2], b[~mask2])
        assert np.abs(a[mask2] - b[mask2]).max() > 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k_steps", [1, 3])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("r,nb,blk,n_sel,tr", [
    (32, 8, 8, 3, 32),        # odd n_sel
    (64, 4, 16, 2, 32),
    (128, 2, 128, 1, 128),    # MXU-aligned full-config block
    (48, 6, 8, 6, 16),        # full selection: every block overwritten
])
def test_block_scatter_update_sweep(dtype, k_steps, n_shards, r, nb, blk,
                                    n_sel, tr):
    rng = np.random.default_rng(r * 3 + nb + k_steps * n_shards)
    n = n_shards * nb * blk
    w = jnp.asarray(rng.normal(size=(k_steps, r, n)), dtype)
    upd = jnp.asarray(rng.normal(size=(k_steps, r, n_shards, n_sel, blk)),
                      dtype)
    idx = _sel_idx(rng, (k_steps, n_shards), nb, n_sel)
    out = block_scatter_update_kernel(w, upd, idx, tr=tr, interpret=True)
    want = ref.block_scatter_update_ref(w, upd, idx, blk)
    # pure write routing — must be exact in any dtype
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


@given(
    k_steps=st.sampled_from([1, 3]), s=st.sampled_from([1, 2, 4]),
    r_t=st.integers(1, 4), nb=st.integers(2, 8),
    blk=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_block_scatter_update_property(k_steps, s, r_t, nb, blk, seed):
    rng = np.random.default_rng(seed)
    r = 16 * r_t
    n = s * nb * blk
    w = jnp.asarray(rng.normal(size=(k_steps, r, n)), jnp.float32)
    n_sel = int(rng.integers(1, nb + 1))
    idx = _sel_idx(rng, (k_steps, s), nb, n_sel)
    upd = jnp.asarray(rng.normal(size=(k_steps, r, s, n_sel, blk)),
                      jnp.float32)
    out = block_scatter_update_kernel(w, upd, idx, tr=16, interpret=True)
    want = ref.block_scatter_update_ref(w, upd, idx, blk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", ["sgd", "momentum", "adamw"])
@pytest.mark.parametrize("k_steps,n_shards,nb,n_sel", [
    (1, 1, 4, 3),             # odd n_sel
    (3, 2, 4, 2),
    (1, 4, 3, 1),
    (3, 1, 6, 5),             # odd n_sel
])
def test_fused_block_opt_parity(dtype, kind, k_steps, n_shards, nb, n_sel):
    """Fused gather+rule+writeback kernel vs the un-fused oracle: SGD is
    bitwise; momentum/AdamW allclose (fp32 state updated in the same pass,
    deselected blocks untouched)."""
    rng = np.random.default_rng(k_steps * 13 + n_shards * 5 + nb)
    r, blk = 48, 8
    n = n_shards * nb * blk
    w = jnp.asarray(rng.normal(size=(k_steps, r, n)), dtype)
    g = jnp.asarray(rng.normal(size=(k_steps, r, n_shards, n_sel, blk)),
                    dtype)
    idx = _sel_idx(rng, (k_steps, n_shards), nb, n_sel)
    mu = nu = None
    if kind in ("momentum", "adamw"):
        mu = jnp.asarray(rng.normal(size=(k_steps, r, n)), jnp.float32)
    if kind == "adamw":
        nu = jnp.abs(jnp.asarray(rng.normal(size=(k_steps, r, n)),
                                 jnp.float32))
    lr, t = jnp.float32(0.05), jnp.float32(3.0)
    hp = dict(kind=kind, momentum=0.9, beta1=0.9, beta2=0.999, eps=1e-8,
              weight_decay=0.01)
    got = fused_block_opt_kernel(w, g, idx, lr, t, mu, nu, tr=16,
                                 interpret=True, **hp)
    # jit the oracle: bitwise means compiled-vs-compiled — XLA contracts
    # `p - lr*g` into an FMA in both, while an eager oracle rounds twice
    # and differs by 1 ulp on ~5% of elements
    import functools
    want = jax.jit(functools.partial(ref.fused_block_opt_ref, **hp))(
        w, g, idx, lr, t, mu, nu)
    for a, b in zip(got, want):
        assert (a is None) == (b is None)
        if a is None:
            continue
        a32, b32 = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if kind == "sgd" and dtype == jnp.float32:
            np.testing.assert_array_equal(a32, b32)
        elif kind == "sgd":
            # bf16 param cast may land on the adjacent value (1 ulp) when
            # the fp32 intermediate sits on an FMA rounding boundary
            np.testing.assert_allclose(a32, b32, rtol=1e-2, atol=1e-7)
        else:
            np.testing.assert_allclose(a32, b32, rtol=1e-6, atol=1e-6)


def test_fused_block_opt_freezes_deselected():
    """Deselected blocks — weights AND optimizer state — come back bitwise
    untouched (the in-place aliasing writes only selected blocks)."""
    rng = np.random.default_rng(0)
    k_steps, r, s, nb, blk, n_sel = 2, 32, 2, 4, 8, 1
    n = s * nb * blk
    w = jnp.asarray(rng.normal(size=(k_steps, r, n)), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(k_steps, r, n)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(k_steps, r, s, n_sel, blk)), jnp.float32)
    idx = _sel_idx(rng, (k_steps, s), nb, n_sel)
    w2, mu2, _ = fused_block_opt_kernel(w, g, idx, jnp.float32(0.1),
                                        jnp.float32(1.0), mu, kind="momentum",
                                        momentum=0.9, tr=16, interpret=True)
    sel_mask = np.zeros((k_steps, r, s, nb, blk), bool)
    for kk in range(k_steps):
        for si in range(s):
            sel_mask[kk, :, si, np.asarray(idx)[kk, si], :] = True
    sel_mask = sel_mask.reshape(k_steps, r, n)
    for before, after in ((w, w2), (mu, mu2)):
        b, a = np.asarray(before), np.asarray(after)
        np.testing.assert_array_equal(a[~sel_mask], b[~sel_mask])
        assert np.abs(a[sel_mask] - b[sel_mask]).max() > 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,c,tr,tc,blk,thr", [
    (64, 64, 32, 32, 2, 0.15),
    (128, 256, 64, 128, 2, 0.15),
    (32, 128, 32, 64, 4, 0.3),
    (256, 512, 256, 512, 2, 0.05),
])
def test_block_act_prune_sweep(dtype, r, c, tr, tc, blk, thr):
    rng = np.random.default_rng(r + c)
    x = jnp.asarray(rng.normal(size=(r, c)) * 0.3, dtype)
    out = block_act_prune_kernel(x, threshold=thr, block=blk, tr=tr, tc=tc,
                                 interpret=True)
    want = ref.block_act_prune_ref(x, thr, blk)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


def test_kernel_integrates_with_smm_grad():
    """kernels-enabled smm backward == jnp smm backward == masked dense."""
    from repro.core.sparse_update import SelSpec, smm, use_kernels
    rng = np.random.default_rng(0)
    k, n = 32, 64
    x = jnp.asarray(rng.normal(size=(4, 16, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    spec = SelSpec(block=16, n_shards=1, n_sel=2, n_blocks=4)
    idx = jnp.asarray([[0, 3]], jnp.int32)
    sel = ({"w": idx}, {"w": spec})
    g_jnp = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    with use_kernels(True):
        g_kern = jax.grad(lambda w: (smm(x, w, sel, "w") ** 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g_kern), np.asarray(g_jnp),
                               rtol=1e-4, atol=1e-4)


def test_compact_dw_full_selection_view_path():
    """The jnp fallback's full-selection branch (einsum on a reshaped view,
    reorder on the output) matches the gather-first path exactly."""
    from repro.core.sparse_update import SelSpec, _gather_blocks, compact_dw
    rng = np.random.default_rng(4)
    m, k, s, nb, blk = 64, 32, 2, 4, 8
    spec = SelSpec(block=blk, n_shards=s, n_sel=nb, n_blocks=nb)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, s * nb * blk)), jnp.float32)
    idx = _sel_idx(rng, (s,), nb, nb)        # full selection, permuted order
    got = compact_dw(x, dy, idx, spec)
    want = jnp.einsum("mk,msnb->ksnb", x, _gather_blocks(dy, idx, spec),
                      preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_block_act_prune_nd():
    from repro.kernels import ops
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 64)) * 0.2,
                    jnp.float32)
    out = ops.block_act_prune(x, threshold=0.15, block=2)
    want = ref.block_act_prune_ref(x, 0.15, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("bh,t,d", [(3, 128, 16), (2, 64, 32), (1, 256, 8)])
def test_wkv6_chunk_kernel(chunk, bh, t, d):
    """Chunked WKV6 kernel == sequential recurrence oracle."""
    from repro.kernels.wkv6_chunk import wkv6_chunk_kernel
    rng = np.random.default_rng(bh * t + d)
    r = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, t, d)) * 0.5, jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.asarray(rng.normal(size=(bh, t, d)) - 1.0,
                                     jnp.float32)))
    u = jnp.asarray(rng.normal(size=(d,)) * 0.3, jnp.float32)
    out = wkv6_chunk_kernel(r, k, v, w, u, chunk=min(chunk, t),
                            interpret=True)
    want = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
