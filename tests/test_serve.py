"""Paged continuous-batching serving engine tests.

The core of this suite is the cross-family serving PARITY contract: for
every cache family (dense-paged llama3, ring+paged gemma, rwkv state,
jamba hybrid state), the paged engine's greedy output must be
token-identical to the contiguous batch=1 oracle (an explicit
``D.prefill`` + ``D.decode_step`` loop that never touches the paged code
paths), across prompt lengths straddling page boundaries and through
mid-stream cancellation. On top of that: page accounting (cancelled and
timed-out requests never count), radix prefix sharing for EVERY family
(hit rate > 0, LOWER page peak than no-sharing, COW splits on shared
partial pages, recurrent-state snapshot restore token-identical to the
no-sharing oracle, strict radix-vs-chain wins, spill-tier persistence
across engine restarts), slot-refill parity, the per-step PRNG split for
placeholder embeds, sampling, the EOS hook, and the PR-2 satellite fixes
(memory-budget solver warning, SIGINT opt-in preemption).
"""
import signal
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseUpdateConfig, get_smoke_config
from repro.models import decoding as D
from repro.models import transformer as T
from repro.serve import Request, ServeEngine
from repro.serve.engine import (make_branching_prefix_requests,
                                make_random_requests,
                                make_shared_prefix_requests)

PROMPT_LEN = 16
GEN_LEN = 8
PAGE = 4          # small pages: multi-page prompts stay cheap to compile

FAMILY_ARCHS = ("llama3-8b", "gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b")


def _engine(arch, num_slots, max_len=PROMPT_LEN + GEN_LEN, **kw):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, num_slots=num_slots,
                            max_len=max_len, **kw)


def _oracle_decode(cfg, params, toks, gen_len, max_len):
    """Contiguous batch=1 greedy ground truth: explicit prefill +
    decode_step loop, no serve/paging code involved."""
    logits, cache = D.prefill(cfg, params,
                              {"tokens": jnp.asarray(toks)[None]},
                              pad_to=max_len)
    ref = [int(jnp.argmax(logits, -1)[0])]
    for t in range(len(toks), len(toks) + gen_len - 1):
        db = {"tokens": jnp.asarray([[ref[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        ref.append(int(jnp.argmax(logits, -1)[0]))
    return ref


# ---------------------------------------------------------------------------
# cross-family parity: paged engine vs contiguous batch=1 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_parity_across_page_boundaries(arch):
    """Greedy serving must be token-identical to the contiguous oracle for
    prompts of PAGE-1 / PAGE / PAGE+1 tokens (chunked prefill hits the
    partial-chunk, exact-page, and page-straddling admission paths)."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gen = 6
    max_len = PAGE + 1 + gen
    engine = ServeEngine(cfg, params, num_slots=2, max_len=max_len,
                         page_size=PAGE)
    rng = np.random.default_rng(11)
    for plen in (PAGE - 1, PAGE, PAGE + 1):
        toks = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        served = engine.run([Request(0, gen, tokens=toks)]).results[0].tokens
        ref = _oracle_decode(cfg, params, toks, gen, max_len)
        assert served == ref, (
            f"{arch} plen={plen}: paged engine diverged from oracle")


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_parity_midstream_cancellation(arch):
    """A request cancelled after k streamed tokens must have produced
    exactly the oracle's first k tokens, and its tokens/requests must land
    in the cancelled counters only."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gen, cut = 6, 3
    max_len = PAGE + 1 + gen
    toks = np.random.default_rng(13).integers(
        0, cfg.vocab_size, PAGE + 1).astype(np.int32)
    ref = _oracle_decode(cfg, params, toks, gen, max_len)

    streamed = []

    def cb(rid, tok):
        streamed.append(tok)
        return len(streamed) < cut

    engine = ServeEngine(cfg, params, num_slots=2, max_len=max_len,
                         page_size=PAGE)
    stats = engine.run([Request(0, gen, tokens=toks, stream=cb)])
    assert streamed == ref[:cut], f"{arch}: cancelled stream != oracle prefix"
    assert stats.results[0].status == "cancelled"
    assert stats.requests_completed == 0 and stats.tokens_out == 0
    assert stats.requests_cancelled == 1 and stats.tokens_cancelled == cut


def test_chunked_prefill_single_trace():
    """Trace-count regression: the final partial prefill chunk is padded to
    page_size under the per-row length mask, so prompts of length ps-3,
    ps-2, ps-1 must compile `paged_step` ONCE for prefill (plus once for
    the decode shape) — not once per distinct residue."""
    cfg, engine = _engine("llama3-8b", num_slots=2,
                          max_len=2 * PAGE + GEN_LEN, page_size=PAGE)
    rng = np.random.default_rng(0)
    lens = [PAGE - 3, PAGE - 2, PAGE - 1]
    assert all(p >= 1 for p in lens)
    reqs = [Request(i, 3, tokens=rng.integers(
                0, cfg.vocab_size, plen).astype(np.int32))
            for i, plen in enumerate(lens)]
    stats = engine.run(reqs)
    assert stats.requests_completed == 3
    # one prefill trace (B=1, S=PAGE) + one decode trace (B=slots, S=1)
    assert engine._step._cache_size() == 2, engine._step._cache_size()


def test_exact_page_multiple_prompts_share_last_page():
    """The fill==0 prefix-cache edge, end to end: identical prompts whose
    length is an EXACT page multiple register no partial entry, yet later
    admissions must still reuse the registrant's last full page as a ps-1
    partial match (reading a prefix of a cached page is position-safe) —
    with output tokens identical to the no-sharing run."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    plen, gen = 2 * PAGE, 4
    toks = np.random.default_rng(23).integers(
        0, cfg.vocab_size, plen).astype(np.int32)

    def run(sharing):
        engine = ServeEngine(cfg, params, num_slots=2, max_len=plen + gen,
                             page_size=PAGE, prefix_sharing=sharing)
        return engine.run([Request(i, gen, tokens=toks.copy())
                           for i in range(3)])

    shared, plain = run(True), run(False)
    assert shared.prefix_hit_tokens > PAGE, (
        "repeat exact-multiple prompts matched only whole pages — the "
        "cached last page was recomputed")
    assert shared.prefill_chunks < plain.prefill_chunks
    assert shared.cow_splits >= 1          # write into the shared last page
    for rid in shared.results:
        assert shared.results[rid].tokens == plain.results[rid].tokens


# ---------------------------------------------------------------------------
# accounting: padded/free slots and cancelled requests must never count
# ---------------------------------------------------------------------------

def test_accounting_no_pad_inflation():
    """requests=5, batch=4: the old launcher padded the last batch with 3
    duplicate prompts and reported 8 requests / 8*gen_len tokens. The
    engine must report exactly 5 and 5*gen_len."""
    cfg, engine = _engine("llama3-8b", num_slots=4)
    reqs = make_random_requests(cfg, 5, PROMPT_LEN, GEN_LEN, seed=0)
    stats = engine.run(reqs)
    assert stats.requests_completed == 5
    assert stats.tokens_out == 5 * GEN_LEN
    assert len(stats.results) == 5
    assert all(len(r.tokens) == GEN_LEN for r in stats.results.values())
    assert stats.refills == 1          # the 5th request recycled a slot
    assert stats.latency_p95_s >= stats.latency_p50_s >= 0.0


def test_cancellation_accounting_regression():
    """The PR-2 pad-slot bug class, now for cancellations: a cancelled
    request must not count toward completed requests or generated tokens —
    neither in the engine stats nor in the benchmark's accounting."""
    cfg, engine = _engine("llama3-8b", num_slots=2, page_size=PAGE)
    reqs = make_random_requests(cfg, 4, PROMPT_LEN, GEN_LEN, seed=0)
    cut = GEN_LEN // 2
    seen = {"n": 0}

    def stop(rid, tok):
        seen["n"] += 1
        return seen["n"] < cut

    reqs[1].stream = stop
    stats = engine.run(reqs)
    assert stats.requests_completed == 3
    assert stats.requests_cancelled == 1
    assert stats.tokens_out == 3 * GEN_LEN        # cancelled tokens excluded
    assert stats.tokens_cancelled == cut
    assert stats.results[1].status == "cancelled"
    assert len(stats.results[1].tokens) == cut


def test_timeout_cancels_without_counting():
    """A request whose deadline passed while queued is dropped unadmitted;
    it must not count toward completed requests or tokens."""
    cfg, engine = _engine("llama3-8b", num_slots=1, page_size=PAGE)
    toks = np.random.default_rng(5).integers(
        0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    ok = Request(0, 4, tokens=toks)
    late = Request(1, 4, tokens=toks, timeout_s=0.0)
    stats = engine.run([ok, late])
    assert stats.requests_completed == 1 and stats.tokens_out == 4
    assert stats.requests_cancelled == 1
    assert stats.results[1].status == "cancelled"
    assert stats.results[1].tokens == []


def test_benchmark_cli_exact_counts(capsys):
    """The acceptance-criteria invocation, via the benchmark entrypoint —
    including a cancelled request that must not inflate the counters."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import serve_throughput
    stats = serve_throughput.main(
        ["--arch", "llama3-8b", "--smoke", "--requests", "7", "--batch", "4",
         "--prompt-len", str(PROMPT_LEN), "--gen-len", str(GEN_LEN)]
    )["llama3-8b"]
    assert stats.requests_completed == 7
    assert stats.tokens_out == 7 * GEN_LEN
    out = capsys.readouterr().out
    assert "requests_completed=7" in out
    assert f"tokens_out={7 * GEN_LEN}" in out

    stats = serve_throughput.main(
        ["--arch", "llama3-8b", "--smoke", "--requests", "8", "--batch", "4",
         "--prompt-len", str(PROMPT_LEN), "--gen-len", str(GEN_LEN),
         "--cancel-frac", "0.25"]
    )["llama3-8b"]
    assert stats.requests_completed == 6
    assert stats.requests_cancelled == 2
    assert stats.tokens_out == 6 * GEN_LEN


# ---------------------------------------------------------------------------
# prefix sharing: hit rate, COW, peak-page reduction — all token-identical
# ---------------------------------------------------------------------------

def test_prefix_sharing_hits_and_lowers_peak():
    """System-prompt workload on the fully-paged family: sharing must show
    prefix hits, COW splits on the shared partial page, a LOWER page-pool
    peak than the same workload without sharing — and identical tokens."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def run(sharing):
        engine = ServeEngine(cfg, params, num_slots=2, max_len=20,
                             page_size=PAGE, num_pages=16,
                             prefix_sharing=sharing)
        return engine.run(make_shared_prefix_requests(
            cfg, 8, prefix_len=12, prompt_len=14, gen_len=5, seed=3))

    shared, plain = run(True), run(False)
    assert shared.prefix_hit_rate > 0
    assert shared.cow_splits >= 1
    assert shared.pages_peak < plain.pages_peak
    assert shared.prefill_chunks < plain.prefill_chunks   # compute skipped
    assert plain.prefix_hit_tokens == 0
    assert shared.requests_completed == plain.requests_completed == 8
    for rid in shared.results:
        assert shared.results[rid].tokens == plain.results[rid].tokens, (
            "prefix sharing changed decoded tokens")


def test_tight_pool_shared_prefix_cannot_deadlock():
    """Regression: with a pool exactly as large as one request's worst case,
    a prefix match can pin the very cache pages whose eviction the
    reservation counts on (matched pages have ref 2, unevictable). The
    engine must fall back to unshared admission — never spin forever — and
    the rolled-back match must not inflate the prefix counters."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    tail = rng.integers(0, cfg.vocab_size, 2).astype(np.int32)
    a = Request(0, 3, tokens=prefix)                          # registers 6 tokens
    b = Request(1, 4, tokens=np.concatenate([prefix, tail]))  # needs all 3 pages
    engine = ServeEngine(cfg, params, num_slots=1, max_len=12,
                         page_size=PAGE, num_pages=3)
    stats = engine.run([a, b])
    assert stats.requests_completed == 2
    assert stats.prefix_hit_tokens <= stats.prefix_lookup_tokens


def test_prefix_mode_resolution_all_families_share():
    """The old fully-paged-only gate is gone: every cache family shares
    prefixes through the radix tree (state families via page-boundary
    snapshots). Only embed-input archs — no token identity to key on —
    resolve to off, and the legacy chain baseline still gates itself to
    fully-paged configs (it cannot snapshot recurrent state)."""
    assert not D.has_state_layers(get_smoke_config("llama3-8b"))
    for arch in ("gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b"):
        assert D.has_state_layers(get_smoke_config(arch)), arch
    for arch in FAMILY_ARCHS:
        _, engine = _engine(arch, num_slots=2, page_size=PAGE)
        assert engine.prefix_mode == "radix" and engine.prefix_sharing, arch
    _, engine = _engine("musicgen-medium", num_slots=2, page_size=PAGE)
    assert engine.prefix_mode == "off"
    _, engine = _engine("llama3-8b", num_slots=2, page_size=PAGE,
                        prefix_mode="chain")
    assert engine.prefix_mode == "chain"
    _, engine = _engine("rwkv6-3b", num_slots=2, page_size=PAGE,
                        prefix_mode="chain")
    assert engine.prefix_mode == "off"


def test_snapshot_row_bytes_matches_extracted_row():
    """CacheFamily byte accounting must equal the real nbytes of one
    extracted per-slot state row — the snapshot LRU budgets on it."""
    for arch in FAMILY_ARCHS:
        cfg = get_smoke_config(arch)
        state, _pools = D.init_serve_cache(cfg, 2, PROMPT_LEN + GEN_LEN,
                                           num_pages=4, page_size=PAGE)
        row = D.cache_extract_row(state, 0)
        want = sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(row))
        got = D.snapshot_row_bytes(cfg, PROMPT_LEN + GEN_LEN)
        assert got == want, f"{arch}: {got} != {want}"
    assert D.snapshot_row_bytes(get_smoke_config("llama3-8b"),
                                PROMPT_LEN + GEN_LEN) == 0


def test_state_only_arch_uses_no_pages():
    cfg, engine = _engine("rwkv6-3b", num_slots=2, page_size=PAGE)
    stats = engine.run(make_random_requests(cfg, 3, PROMPT_LEN, 4, seed=0))
    assert stats.requests_completed == 3
    assert stats.pages_total == 0 and stats.pages_peak == 0


# ---------------------------------------------------------------------------
# recurrent-state snapshots: state families share prefixes token-identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ("gemma3-4b", "rwkv6-3b",
                                  "jamba-1.5-large-398b"))
def test_state_family_prefix_parity_and_snapshot_hits(arch):
    """Shared-prefix workload on the ring/state families: admissions must
    restore page-boundary snapshots (hit rate > 0), skip prefill chunks,
    and decode token-identically to the no-sharing run."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def run(sharing):
        engine = ServeEngine(cfg, params, num_slots=2, max_len=20,
                             page_size=PAGE, num_pages=16,
                             prefix_sharing=sharing)
        return engine.run(make_shared_prefix_requests(
            cfg, 6, prefix_len=12, prompt_len=14, gen_len=5, seed=3))

    shared, plain = run(True), run(False)
    assert shared.snapshot_hits > 0 and shared.snapshot_hit_rate > 0
    assert shared.snapshots_stored > 0
    assert shared.prefix_hit_tokens > 0
    assert shared.prefill_chunks < plain.prefill_chunks
    assert shared.requests_completed == plain.requests_completed == 6
    for rid in shared.results:
        assert shared.results[rid].tokens == plain.results[rid].tokens, (
            f"{arch}: snapshot restore changed decoded tokens")


def test_cancel_while_snapshot_pinned_releases_cleanly():
    """A request cancelled mid-stream still holds its admission pin (the
    snapshot node) — cancellation must release it so the node stays
    reusable AND evictable, and later identical requests decode exactly."""
    cfg = get_smoke_config("rwkv6-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(3).integers(
        0, cfg.vocab_size, 14).astype(np.int32)
    engine = ServeEngine(cfg, params, num_slots=1, max_len=20,
                         page_size=PAGE)
    streamed = []

    def cb(rid, tok):
        streamed.append(tok)
        return len(streamed) < 2

    stats = engine.run([
        Request(0, 5, tokens=toks.copy()),               # stores snapshots
        Request(1, 5, tokens=toks.copy(), stream=cb),    # hit, then cancel
        Request(2, 5, tokens=toks.copy()),               # hit, completes
    ])
    assert stats.snapshot_hits >= 2
    assert stats.requests_cancelled == 1 and stats.requests_completed == 2
    ref = _oracle_decode(cfg, params, toks, 5, 20)
    assert stats.results[0].tokens == ref
    assert stats.results[2].tokens == ref
    assert streamed == ref[:2]


# ---------------------------------------------------------------------------
# radix vs chain: strictly more reuse on partially-overlapping workloads
# ---------------------------------------------------------------------------

def test_radix_strictly_beats_chain_attention_family():
    """Acceptance: radix shows STRICTLY higher hit tokens and STRICTLY
    fewer prefill chunks than the chain baseline on the zipf-branching
    workload. The tree's host spill tier outlives run(), so a second wave
    of the same workload rehydrates evicted prefixes; the chain baseline
    rebuilds from scratch every run."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def wave():
        return make_branching_prefix_requests(
            cfg, 6, prompt_len=14, gen_len=4, page_size=PAGE,
            max_prefix_pages=2, seed=5)

    def two_waves(mode):
        engine = ServeEngine(cfg, params, num_slots=2, max_len=20,
                             page_size=PAGE, num_pages=16, prefix_mode=mode)
        return engine.run(wave()), engine.run(wave())

    (r1, r2) = two_waves("radix")
    (c1, c2) = two_waves("chain")
    assert r2.prefix_hit_tokens > c2.prefix_hit_tokens
    assert r2.prefill_chunks < c2.prefill_chunks
    assert r2.rehydrates > 0 and r1.spills > 0
    for rid in r2.results:      # reuse must never change decoded tokens
        assert r1.results[rid].tokens == r2.results[rid].tokens \
            == c1.results[rid].tokens == c2.results[rid].tokens, rid


def test_radix_strictly_beats_chain_state_family():
    """Same acceptance bar for a state family: the chain design cannot
    snapshot recurrent state (it resolves to off), the radix tree can."""
    cfg = get_smoke_config("rwkv6-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def run(mode):
        engine = ServeEngine(cfg, params, num_slots=2, max_len=20,
                             page_size=PAGE, prefix_mode=mode)
        assert engine.prefix_mode == ("off" if mode == "chain" else mode)
        return engine.run(make_shared_prefix_requests(
            cfg, 6, prefix_len=12, prompt_len=14, gen_len=4, seed=7))

    radix, chain = run("radix"), run("chain")
    assert radix.prefix_hit_tokens > chain.prefix_hit_tokens == 0
    assert radix.prefill_chunks < chain.prefill_chunks
    for rid in radix.results:
        assert radix.results[rid].tokens == chain.results[rid].tokens, rid


# ---------------------------------------------------------------------------
# persistence: the spill tier survives engine restarts via --prefix-persist
# ---------------------------------------------------------------------------

def test_prefix_persist_survives_restart(tmp_path):
    """A NEW engine pointed at the same persist dir must serve the first
    repeated prompt with a prefix hit (rehydrated from the restored spill
    tier), token-identical to a no-sharing engine; a meta mismatch (other
    page size) must cold-start instead of corrupting."""
    cfg = get_smoke_config("llama3-8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def reqs():
        return make_shared_prefix_requests(cfg, 3, prefix_len=8,
                                           prompt_len=10, gen_len=4, seed=9)

    def engine(page=PAGE):
        return ServeEngine(cfg, params, num_slots=2, max_len=16,
                           page_size=page, num_pages=16,
                           prefix_persist=str(tmp_path))

    first = engine().run(reqs())
    assert first.spill_entries > 0          # run() end spilled the tree
    second = engine().run(reqs())           # fresh engine, same dir
    assert second.rehydrates > 0
    assert second.prefix_hit_tokens > 0
    plain = ServeEngine(cfg, params, num_slots=2, max_len=16,
                        page_size=PAGE, num_pages=16,
                        prefix_sharing=False).run(reqs())
    for rid in second.results:
        assert second.results[rid].tokens == plain.results[rid].tokens, rid
    third = engine(page=2 * PAGE).run(reqs())
    assert third.rehydrates == 0            # meta mismatch -> cold start


# ---------------------------------------------------------------------------
# slot-refill parity: a request admitted mid-flight into a dirty slot must
# decode exactly as the same prompt served alone (pins cache row ops)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_slot_refill_parity(arch):
    cfg, engine = _engine(arch, num_slots=2, page_size=PAGE)
    rng = np.random.default_rng(7)

    def req(rid, gen):
        if cfg.embed_inputs:
            return Request(rid, gen, embeds=rng.standard_normal(
                (PROMPT_LEN, cfg.d_model)).astype(np.float32))
        return Request(rid, gen, tokens=rng.integers(
            0, cfg.vocab_size, PROMPT_LEN).astype(np.int32))

    filler0, filler1, target = req(0, GEN_LEN), req(1, 2), req(2, GEN_LEN)
    stats = engine.run([filler0, filler1, target])
    assert stats.refills >= 1, "target was not admitted into a used slot"
    assert stats.requests_completed == 3

    _, ref_engine = _engine(arch, num_slots=2, page_size=PAGE)
    alone = ref_engine.run([Request(2, GEN_LEN, tokens=target.tokens,
                                    embeds=target.embeds)])
    assert alone.results[2].tokens == stats.results[2].tokens, (
        f"{arch}: refilled-slot decode diverged from solo decode")


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_engine_matches_ground_truth_decode(arch):
    """Engine-vs-oracle parity at the default page size (prompt spans one
    page exactly). Unlike the refill parity test, the reference here does
    not go through the engine, so systematic position/cache bugs cannot
    cancel out."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_len = PROMPT_LEN + GEN_LEN
    engine = ServeEngine(cfg, params, num_slots=2, max_len=max_len)
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    served = engine.run([Request(0, GEN_LEN, tokens=toks)]).results[0].tokens
    ref = _oracle_decode(cfg, params, toks, GEN_LEN, max_len)
    assert served == ref, f"{arch}: engine diverged from decode oracle"


def test_short_prompt_mamba_conv_state_parity():
    """Prompts shorter than d_conv-1 must yield the same (left-zero-padded)
    conv history semantics as the full-prompt oracle."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    plen = cfg.ssm.d_conv - 2          # shorter than the conv history
    assert plen >= 1
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, num_slots=2, max_len=plen + GEN_LEN,
                         page_size=PAGE)
    toks = np.random.default_rng(5).integers(
        0, cfg.vocab_size, plen).astype(np.int32)
    served = engine.run([Request(0, GEN_LEN, tokens=toks)]).results[0].tokens
    ref = _oracle_decode(cfg, params, toks, GEN_LEN, plen + GEN_LEN)
    assert served == ref


def test_window_larger_than_max_len_serves():
    """sliding_window > max_len must serve (the ring is capped at the cache
    capacity) for both window regimes."""
    cfg = get_smoke_config("gemma3-4b")
    assert cfg.sliding_window > 0
    prompt_len, gen_len = cfg.sliding_window, 4       # max_len > window
    short = cfg.sliding_window // 2                   # max_len < window
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    for plen in (prompt_len, short):
        engine = ServeEngine(cfg, params, num_slots=2,
                             max_len=plen + gen_len, page_size=PAGE)
        reqs = make_random_requests(cfg, 3, plen, gen_len, seed=0)
        stats = engine.run(reqs)
        assert stats.requests_completed == 3
        assert stats.tokens_out == 3 * gen_len


def test_cache_row_ops_roundtrip():
    """insert/extract/reset on every cache kind of the dense config."""
    cfg = get_smoke_config("llama3-8b")
    big = D.init_cache(cfg, 4, 32)
    row = jax.tree.map(
        lambda a: jnp.full((a.shape[0], 1) + a.shape[2:], 3, a.dtype),
        D.init_cache(cfg, 1, 32))
    ins = D.cache_insert_row(big, row, 2)
    got = D.cache_extract_row(ins, 2)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()), got, row))
    # other rows untouched
    assert jax.tree.all(jax.tree.map(
        lambda a: bool((np.asarray(a)[:, [0, 1, 3]] == 0).all()), ins))
    rst = D.cache_reset_row(ins, 2)
    assert jax.tree.all(jax.tree.map(
        lambda a: bool((np.asarray(a) == 0).all()), rst))


# ---------------------------------------------------------------------------
# input path: per-step PRNG split for placeholder embeds
# ---------------------------------------------------------------------------

def test_embed_input_key_split_per_step():
    """The old serve loop reused one key for every step's placeholder
    embeds (identical decode inputs each step). Consecutive engine steps
    must draw different embeds."""
    cfg, engine = _engine("musicgen-medium", num_slots=2)
    a = np.asarray(engine._decode_batch([0, 0], [1, 1])["embeds"])
    b = np.asarray(engine._decode_batch([0, 0], [1, 1])["embeds"])
    assert not np.array_equal(a, b)


def test_embed_inputs_arch_serves():
    cfg, engine = _engine("musicgen-medium", num_slots=2, page_size=PAGE)
    stats = engine.run(make_random_requests(cfg, 3, PROMPT_LEN, 4, seed=0))
    assert stats.requests_completed == 3
    assert stats.tokens_out == 3 * 4


# ---------------------------------------------------------------------------
# sampling + EOS hook
# ---------------------------------------------------------------------------

def test_temperature_sampling_deterministic_per_seed():
    cfg, e1 = _engine("llama3-8b", num_slots=2, temperature=0.8, seed=3)
    reqs = make_random_requests(cfg, 3, PROMPT_LEN, GEN_LEN, seed=0)
    s1 = e1.run(reqs)
    _, e2 = _engine("llama3-8b", num_slots=2, temperature=0.8, seed=3)
    s2 = e2.run(reqs)
    assert [r.tokens for r in s1.results.values()] == \
           [r.tokens for r in s2.results.values()]
    assert all(0 <= t < cfg.vocab_size
               for r in s1.results.values() for t in r.tokens)


def test_eos_hook_stops_early():
    cfg, engine = _engine("llama3-8b", num_slots=1)
    reqs = make_random_requests(cfg, 1, PROMPT_LEN, GEN_LEN, seed=0)
    first = engine.run(reqs).results[0].tokens[0]
    _, engine2 = _engine("llama3-8b", num_slots=1, eos_id=first)
    stats = engine2.run(make_random_requests(cfg, 1, PROMPT_LEN, GEN_LEN,
                                             seed=0))
    assert stats.results[0].tokens == [first]    # stopped at the EOS token
    assert stats.requests_completed == 1
    assert stats.tokens_out == 1


# ---------------------------------------------------------------------------
# satellite: memory-budget solver must not silently blow the budget
# ---------------------------------------------------------------------------

def test_solve_max_layers_warns_when_budget_impossible():
    from repro.core.memory import solve_max_layers, training_extra_bytes
    cfg = get_smoke_config("llama3-8b")
    sp = SparseUpdateConfig(update_ratio=0.2, channel_block=8,
                            memory_budget_bytes=16)   # tiny: nothing fits
    assert training_extra_bytes(cfg, sp, 1, 1024) > sp.memory_budget_bytes
    with pytest.warns(UserWarning, match="cannot fit even one"):
        assert solve_max_layers(cfg, sp, 1024) == 1
    with pytest.raises(ValueError, match="cannot fit even one"):
        solve_max_layers(cfg, sp, 1024, strict=True)
    # a sane budget solves without warning
    sp_ok = SparseUpdateConfig(update_ratio=0.2, channel_block=8,
                               memory_budget_bytes=1 << 30)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert solve_max_layers(cfg, sp_ok, 1024) >= 1


# ---------------------------------------------------------------------------
# satellite: SIGINT is opt-in for the preemption handler
# ---------------------------------------------------------------------------

def test_preemption_handler_sigint_optin():
    from repro.runtime.fault import PreemptionHandler
    before_term = signal.getsignal(signal.SIGTERM)
    before_int = signal.getsignal(signal.SIGINT)
    with PreemptionHandler() as h:
        assert signal.getsignal(signal.SIGTERM) == h._handle
        assert signal.getsignal(signal.SIGINT) == before_int  # untouched
    assert signal.getsignal(signal.SIGTERM) == before_term
    with PreemptionHandler(include_sigint=True) as h:
        assert signal.getsignal(signal.SIGTERM) == h._handle
        assert signal.getsignal(signal.SIGINT) == h._handle
        assert not h.preempted
        signal.raise_signal(signal.SIGINT)
        assert h.preempted
    assert signal.getsignal(signal.SIGTERM) == before_term
    assert signal.getsignal(signal.SIGINT) == before_int
