"""Continuous-batching serving engine tests.

Pins the two launch/serve.py accounting bugs this subsystem replaced
(padded slots counted as completed requests and as generated tokens), the
cache row ops behind slot refill (decode-vs-prefill parity when a request
is admitted mid-flight into a dirty slot), the per-step PRNG split on the
placeholder-embeds input path, sampling, the EOS hook, and the two
satellite fixes (memory-budget solver warning, SIGINT opt-in preemption).
"""
import signal
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseUpdateConfig, get_smoke_config
from repro.models import decoding as D
from repro.models import transformer as T
from repro.serve import Request, ServeEngine
from repro.serve.engine import make_random_requests

PROMPT_LEN = 16
GEN_LEN = 8

FAMILY_ARCHS = ("llama3-8b", "gemma3-4b", "rwkv6-3b", "jamba-1.5-large-398b")


def _engine(arch, num_slots, max_len=PROMPT_LEN + GEN_LEN, **kw):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, num_slots=num_slots,
                            max_len=max_len, **kw)


# ---------------------------------------------------------------------------
# accounting: padded/free slots must never count
# ---------------------------------------------------------------------------

def test_accounting_no_pad_inflation():
    """requests=5, batch=4: the old launcher padded the last batch with 3
    duplicate prompts and reported 8 requests / 8*gen_len tokens. The
    engine must report exactly 5 and 5*gen_len."""
    cfg, engine = _engine("llama3-8b", num_slots=4)
    reqs = make_random_requests(cfg, 5, PROMPT_LEN, GEN_LEN, seed=0)
    stats = engine.run(reqs)
    assert stats.requests_completed == 5
    assert stats.tokens_out == 5 * GEN_LEN
    assert len(stats.results) == 5
    assert all(len(r.tokens) == GEN_LEN for r in stats.results.values())
    assert stats.refills == 1          # the 5th request recycled a slot
    assert stats.latency_p95_s >= stats.latency_p50_s >= 0.0


def test_benchmark_cli_exact_counts(capsys):
    """The acceptance-criteria invocation, via the benchmark entrypoint."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import serve_throughput
    stats = serve_throughput.main(
        ["--arch", "llama3-8b", "--smoke", "--requests", "7", "--batch", "4",
         "--prompt-len", str(PROMPT_LEN), "--gen-len", str(GEN_LEN)]
    )["llama3-8b"]
    assert stats.requests_completed == 7
    assert stats.tokens_out == 7 * GEN_LEN
    out = capsys.readouterr().out
    assert "requests_completed=7" in out
    assert f"tokens_out={7 * GEN_LEN}" in out


# ---------------------------------------------------------------------------
# slot-refill parity: a request admitted mid-flight into a dirty slot must
# decode exactly as the same prompt served alone (pins cache row ops)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_slot_refill_parity(arch):
    cfg, engine = _engine(arch, num_slots=2)
    rng = np.random.default_rng(7)

    def req(rid, gen):
        if cfg.embed_inputs:
            return Request(rid, gen, embeds=rng.standard_normal(
                (PROMPT_LEN, cfg.d_model)).astype(np.float32))
        return Request(rid, gen, tokens=rng.integers(
            0, cfg.vocab_size, PROMPT_LEN).astype(np.int32))

    filler0, filler1, target = req(0, GEN_LEN), req(1, 2), req(2, GEN_LEN)
    stats = engine.run([filler0, filler1, target])
    assert stats.refills >= 1, "target was not admitted into a used slot"
    assert stats.requests_completed == 3

    _, ref_engine = _engine(arch, num_slots=2)
    alone = ref_engine.run([Request(2, GEN_LEN, tokens=target.tokens,
                                    embeds=target.embeds)])
    assert alone.results[2].tokens == stats.results[2].tokens, (
        f"{arch}: refilled-slot decode diverged from solo decode")


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_engine_matches_ground_truth_decode(arch):
    """Engine-vs-oracle parity: greedy serving must reproduce an explicit
    prefill + decode_step loop (positions t = prompt_len..) exactly. Unlike
    the refill parity test, the reference here does not go through the
    engine, so systematic position/cache bugs cannot cancel out."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_len = PROMPT_LEN + GEN_LEN
    engine = ServeEngine(cfg, params, num_slots=2, max_len=max_len)
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    served = engine.run([Request(0, GEN_LEN, tokens=toks)]).results[0].tokens

    logits, cache = D.prefill(cfg, params,
                              {"tokens": jnp.asarray(toks)[None]},
                              pad_to=max_len)
    ref = [int(jnp.argmax(logits, -1)[0])]
    for t in range(PROMPT_LEN, max_len - 1):
        db = {"tokens": jnp.asarray([[ref[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        ref.append(int(jnp.argmax(logits, -1)[0]))
    assert served == ref, f"{arch}: engine diverged from decode oracle"


def test_short_prompt_mamba_conv_state_parity():
    """Prompts shorter than d_conv-1 must yield a full-size (left-zero-
    padded) conv history so cache_insert_row never partial-writes a slot."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    plen = cfg.ssm.d_conv - 2          # shorter than the conv history
    assert plen >= 1
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, num_slots=2, max_len=plen + GEN_LEN)
    toks = np.random.default_rng(5).integers(
        0, cfg.vocab_size, plen).astype(np.int32)
    served = engine.run([Request(0, GEN_LEN, tokens=toks)]).results[0].tokens

    logits, cache = D.prefill(cfg, params,
                              {"tokens": jnp.asarray(toks)[None]},
                              pad_to=plen + GEN_LEN)
    ref = [int(jnp.argmax(logits, -1)[0])]
    for t in range(plen, plen + GEN_LEN - 1):
        db = {"tokens": jnp.asarray([[ref[-1]]], jnp.int32),
              "positions": jnp.full((1, 1), t, jnp.int32)}
        logits, cache = D.decode_step(cfg, params, db, cache)
        ref.append(int(jnp.argmax(logits, -1)[0]))
    assert served == ref


def test_window_larger_than_max_len_serves():
    """sliding_window > max_len must serve (the ring is capped at the cache
    capacity), and still match the decode oracle built the same way."""
    cfg = get_smoke_config("gemma3-4b")
    assert cfg.sliding_window > 0
    prompt_len, gen_len = cfg.sliding_window, 4       # max_len > window
    short = cfg.sliding_window // 2                   # max_len < window
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    for plen in (prompt_len, short):
        engine = ServeEngine(cfg, params, num_slots=2,
                             max_len=plen + gen_len)
        reqs = make_random_requests(cfg, 3, plen, gen_len, seed=0)
        stats = engine.run(reqs)
        assert stats.requests_completed == 3
        assert stats.tokens_out == 3 * gen_len


def test_cache_row_ops_roundtrip():
    """insert/extract/reset on every cache kind of the dense config."""
    cfg = get_smoke_config("llama3-8b")
    big = D.init_cache(cfg, 4, 32)
    row = jax.tree.map(
        lambda a: jnp.full((a.shape[0], 1) + a.shape[2:], 3, a.dtype),
        D.init_cache(cfg, 1, 32))
    ins = D.cache_insert_row(big, row, 2)
    got = D.cache_extract_row(ins, 2)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()), got, row))
    # other rows untouched
    assert jax.tree.all(jax.tree.map(
        lambda a: bool((np.asarray(a)[:, [0, 1, 3]] == 0).all()), ins))
    rst = D.cache_reset_row(ins, 2)
    assert jax.tree.all(jax.tree.map(
        lambda a: bool((np.asarray(a) == 0).all()), rst))


# ---------------------------------------------------------------------------
# input path: per-step PRNG split for placeholder embeds
# ---------------------------------------------------------------------------

def test_embed_input_key_split_per_step():
    """The old serve loop reused one key for every step's placeholder
    embeds (identical decode inputs each step). Consecutive engine steps
    must draw different embeds."""
    cfg, engine = _engine("musicgen-medium", num_slots=2)
    a = np.asarray(engine._decode_batch([0, 0], [1, 1])["embeds"])
    b = np.asarray(engine._decode_batch([0, 0], [1, 1])["embeds"])
    assert not np.array_equal(a, b)


def test_embed_inputs_arch_serves():
    cfg, engine = _engine("musicgen-medium", num_slots=2)
    stats = engine.run(make_random_requests(cfg, 3, PROMPT_LEN, 4, seed=0))
    assert stats.requests_completed == 3
    assert stats.tokens_out == 3 * 4


# ---------------------------------------------------------------------------
# sampling + EOS hook
# ---------------------------------------------------------------------------

def test_temperature_sampling_deterministic_per_seed():
    cfg, e1 = _engine("llama3-8b", num_slots=2, temperature=0.8, seed=3)
    reqs = make_random_requests(cfg, 3, PROMPT_LEN, GEN_LEN, seed=0)
    s1 = e1.run(reqs)
    _, e2 = _engine("llama3-8b", num_slots=2, temperature=0.8, seed=3)
    s2 = e2.run(reqs)
    assert [r.tokens for r in s1.results.values()] == \
           [r.tokens for r in s2.results.values()]
    assert all(0 <= t < cfg.vocab_size
               for r in s1.results.values() for t in r.tokens)


def test_eos_hook_stops_early():
    cfg, engine = _engine("llama3-8b", num_slots=1)
    reqs = make_random_requests(cfg, 1, PROMPT_LEN, GEN_LEN, seed=0)
    first = engine.run(reqs).results[0].tokens[0]
    _, engine2 = _engine("llama3-8b", num_slots=1, eos_id=first)
    stats = engine2.run(make_random_requests(cfg, 1, PROMPT_LEN, GEN_LEN,
                                             seed=0))
    assert stats.results[0].tokens == [first]    # stopped at the EOS token
    assert stats.requests_completed == 1
    assert stats.tokens_out == 1


# ---------------------------------------------------------------------------
# satellite: memory-budget solver must not silently blow the budget
# ---------------------------------------------------------------------------

def test_solve_max_layers_warns_when_budget_impossible():
    from repro.core.memory import solve_max_layers, training_extra_bytes
    cfg = get_smoke_config("llama3-8b")
    sp = SparseUpdateConfig(update_ratio=0.2, channel_block=8,
                            memory_budget_bytes=16)   # tiny: nothing fits
    assert training_extra_bytes(cfg, sp, 1, 1024) > sp.memory_budget_bytes
    with pytest.warns(UserWarning, match="cannot fit even one"):
        assert solve_max_layers(cfg, sp, 1024) == 1
    with pytest.raises(ValueError, match="cannot fit even one"):
        solve_max_layers(cfg, sp, 1024, strict=True)
    # a sane budget solves without warning
    sp_ok = SparseUpdateConfig(update_ratio=0.2, channel_block=8,
                               memory_budget_bytes=1 << 30)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert solve_max_layers(cfg, sp_ok, 1024) >= 1


# ---------------------------------------------------------------------------
# satellite: SIGINT is opt-in for the preemption handler
# ---------------------------------------------------------------------------

def test_preemption_handler_sigint_optin():
    from repro.runtime.fault import PreemptionHandler
    before_term = signal.getsignal(signal.SIGTERM)
    before_int = signal.getsignal(signal.SIGINT)
    with PreemptionHandler() as h:
        assert signal.getsignal(signal.SIGTERM) == h._handle
        assert signal.getsignal(signal.SIGINT) == before_int  # untouched
    assert signal.getsignal(signal.SIGTERM) == before_term
    with PreemptionHandler(include_sigint=True) as h:
        assert signal.getsignal(signal.SIGTERM) == h._handle
        assert signal.getsignal(signal.SIGINT) == h._handle
        assert not h.preempted
        signal.raise_signal(signal.SIGINT)
        assert h.preempted
    assert signal.getsignal(signal.SIGTERM) == before_term
    assert signal.getsignal(signal.SIGINT) == before_int
