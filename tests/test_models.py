"""Per-arch smoke tests (reduced configs, CPU): forward + one train step,
shape and finiteness checks, decode==forward consistency, attention
equivalences. The FULL configs are exercised only by the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, OptimizerConfig, ShapeConfig,
                           SparseUpdateConfig, TrainConfig, get_smoke_config)
from repro.models import decoding as D
from repro.models import transformer as T


def _batch(cfg, b=2, s=32, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.dtype(cfg.dtype))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch["positions"] = jnp.stack([pos, pos, pos])
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden, aux = T.forward(cfg, (params, None), batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    loss, metrics = T.loss_fn(cfg, (params, None), batch)
    assert bool(jnp.isfinite(loss))
    # random-init CE should be near ln(V)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_sparse_train_step(arch):
    """One DGSU train step per arch: loss finite, frozen params untouched,
    only selected channel blocks of trainable params change."""
    from repro.train import make_train_state, make_train_step
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("t", 32, 2, "train")
    tc = TrainConfig(
        model=cfg, shape=shape,
        sparse=SparseUpdateConfig(update_ratio=0.5, num_update_layers=1,
                                  channel_block=8, phase_fixed_early=100),
        optimizer=OptimizerConfig(kind="sgd", learning_rate=0.1))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    step_fn = make_train_step(tc, plan)
    batch = _batch(cfg, b=2, s=32)
    new_state, metrics = jax.jit(step_fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # frozen tree bit-identical
    same = jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()),
        state["params_frozen"], new_state["params_frozen"]))
    assert same, "frozen params changed"
    # trainable: some change, and change only within selected blocks for a
    # known selectable leaf
    changed = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                           state["params_trainable"],
                           new_state["params_trainable"])
    assert max(jax.tree.leaves(changed)) > 0, "no parameter moved"


def test_train_decreases_loss_dense_vs_sparse():
    """Paper Table II ordering on the synthetic LM task: full > dynamic
    sparse > frozen (training at all beats nothing)."""
    from repro.data import lm_batches
    from repro.train import make_train_state, make_train_step
    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("t", 16, 16, "train")
    results = {}
    for name, sparse in [
        ("dense", SparseUpdateConfig(enabled=False)),
        ("sparse", SparseUpdateConfig(update_ratio=0.5, num_update_layers=2,
                                      channel_block=16, phase_fixed_early=5,
                                      phase_dynamic=25)),
    ]:
        tc = TrainConfig(model=cfg, shape=shape, sparse=sparse,
                         optimizer=OptimizerConfig(kind="adamw",
                                                   learning_rate=3e-3))
        state, plan = make_train_state(tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(tc, plan))
        losses = []
        for i, b in zip(range(60), lm_batches(16, 16, cfg.vocab_size, seed=3)):
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        results[name] = (float(np.mean(losses[:5])), float(np.mean(losses[-10:])))
    for name, (first, last) in results.items():
        assert last < first - 0.02, f"{name} did not reduce loss: {first}->{last}"
    # dense should fit the task at least as well as sparse
    assert results["dense"][1] <= results["sparse"][1] + 0.05


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-4b", "rwkv6-3b",
                                  "jamba-1.5-large-398b", "deepseek-moe-16b",
                                  "qwen2-vl-7b"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # disable token dropping for exactness
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    key = jax.random.PRNGKey(1)
    batch = _batch(cfg, b, s, key)
    hidden, _ = T.forward(cfg, (params, None), batch)
    w = T.lm_head_weight(cfg, (params, None))
    ref = jnp.einsum("bsd,dv->bsv", hidden, w)

    s0 = s - 4
    pf_batch = {k: (v[:, :s0] if k != "positions" else v[..., :s0])
                for k, v in batch.items() if k != "labels"}
    logits, cache = D.prefill(cfg, params, pf_batch, pad_to=s)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref[:, s0 - 1]),
                               rtol=5e-2, atol=5e-3)
    for t in range(s0, s):
        db = {"positions": jnp.full((b, 1), t, jnp.int32)}
        if cfg.embed_inputs:
            db["embeds"] = batch["embeds"][:, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        if cfg.mrope:
            db["positions"] = jnp.broadcast_to(db["positions"], (3, b, 1))
        logits, cache = D.decode_step(cfg, params, db, cache)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref[:, t]),
                                   rtol=5e-2, atol=5e-3)


def test_flash_equals_dense_attention():
    from repro.models.layers import _sdpa_dense, _sdpa_flash
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, d = 2, 512, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    for w in (0, 100):
        dn = _sdpa_dense(q, k, v, w)
        fl = _sdpa_flash(q, k, v, w, q_chunk=128, kv_chunk=128)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(dn),
                                   rtol=1e-4, atol=1e-5)
        # gradients too (custom flash VJP)
        gf = jax.grad(lambda q, k, v: (_sdpa_flash(q, k, v, w, 128, 128) ** 2
                                       ).sum(), argnums=(0, 1, 2))(q, k, v)
        gd = jax.grad(lambda q, k, v: (_sdpa_dense(q, k, v, w) ** 2
                                       ).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, bb in zip(gf, gd):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       rtol=1e-3, atol=1e-4)


def test_sliding_window_restricts_reach():
    """A token beyond the window must not influence attention output."""
    from repro.models.layers import _sdpa_dense
    key = jax.random.PRNGKey(0)
    b, s, h, d = 1, 64, 2, 8
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    out1 = _sdpa_dense(q, k, v, window=8)
    k2 = k.at[:, 0].set(100.0)
    v2 = v.at[:, 0].set(-100.0)
    out2 = _sdpa_dense(q, k2, v2, window=8)
    # position 0 is outside the window of positions >= 8
    np.testing.assert_allclose(np.asarray(out1[:, 8:]),
                               np.asarray(out2[:, 8:]), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(out1[:, 0] - out2[:, 0]).max()) > 1.0


def test_moe_aux_losses_and_balance():
    from repro.models import moe as MOE
    cfg = get_smoke_config("deepseek-moe-16b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], params["segments"]["blocks"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = MOE.apply_moe(moe_p, cfg, x)
    assert y.shape == x.shape
    assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
    assert bool(jnp.isfinite(y).all())


def test_mamba_chunked_scan_matches_stepwise():
    """Chunked selective scan == naive per-step recurrence."""
    from repro.models import mamba as M
    cfg = get_smoke_config("jamba-1.5-large-398b")
    p = M.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model)) * 0.5
    out_chunked, _ = M.apply_mamba(p, cfg, x)
    # stepwise via decode cache
    cache = M.init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(128):
        o, cache = M.apply_mamba(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_step),
                               rtol=2e-3, atol=2e-4)


def test_rwkv_chunked_matches_stepwise():
    from repro.models import rwkv6 as R
    cfg = get_smoke_config("rwkv6-3b")
    p = R.init_time_mix(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5
    out_full, _ = R.apply_time_mix(p, cfg, x)
    cache = {"s": jnp.zeros((2, R.num_heads(cfg), cfg.rwkv.head_dim,
                             cfg.rwkv.head_dim), jnp.float32),
             "last": jnp.zeros((2, cfg.d_model), jnp.float32)}
    outs = []
    for t in range(64):
        o, cache = R.apply_time_mix(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_step),
                               rtol=2e-3, atol=2e-4)


def test_mobilenet_smoke():
    from repro.configs.mobilenetv2_cifar import smoke_config
    from repro.models import mobilenet_v2 as MN
    cfg = smoke_config()
    params = MN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, cfg.img_size, cfg.img_size, 3))
    logits = MN.forward(cfg, (params, None), imgs)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())
