"""End-to-end behaviour tests of the paper's system (Table II semantics on
the synthetic transfer task, memory claims, update-fraction claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (OptimizerConfig, ShapeConfig, SparseUpdateConfig,
                           TrainConfig, get_smoke_config)


def test_selected_fraction_tracks_ratio():
    """The paper reports updating 2% of conv weights; our selected-fraction
    accounting must scale linearly with r and K."""
    from repro.core import build_plan, selected_fraction
    cfg = get_smoke_config("llama3-8b")
    f = {}
    for r in (0.1, 0.2, 0.4):
        plan = build_plan(cfg, SparseUpdateConfig(update_ratio=r,
                                                  num_update_layers=2,
                                                  channel_block=8))
        f[r] = selected_fraction(plan, cfg)
    assert f[0.1] < f[0.2] < f[0.4]
    assert f[0.4] / f[0.1] == pytest.approx(4.0, rel=0.35)


def test_feature_memory_saving_claim():
    """Paper: 98% feature-memory saving vs dense training (frozen front
    layers never save activations)."""
    from repro.core import memory as mem
    cfg = get_smoke_config("llama3-8b")
    tokens = 1024
    per_layer = mem.activation_bytes_per_layer(cfg, tokens)
    sparse_act = per_layer * 1
    dense_act = per_layer * cfg.num_layers
    assert 1 - sparse_act / dense_act >= 0.6  # smoke model only has 3 layers


def test_cnn_transfer_learns():
    """The synthetic transfer task is learnable: fine-tuning >> no
    fine-tuning (Table II 'Full' vs 'No Fine-tuning' direction)."""
    from repro.data.synthetic import TransferTask
    from repro.models import mobilenet_v2 as MN
    from repro.configs.mobilenetv2_cifar import smoke_config
    from repro.optim import apply_updates, init_opt_state

    cfg = smoke_config()
    task = TransferTask(img=cfg.img_size, seed=0)
    params = MN.init_params(cfg, jax.random.PRNGKey(0))
    oc = OptimizerConfig(kind="momentum", momentum=0.9, learning_rate=0.05)

    def eval_acc(p, n=4):
        accs = []
        for s in range(n):
            b = task.batch(64, 1000 + s, "target")
            _, m = MN.loss_fn(cfg, (None, p), {
                "images": jnp.asarray(b["images"]),
                "labels": jnp.asarray(b["labels"])})
            accs.append(float(m["acc"]))
        return float(np.mean(accs))

    acc0 = eval_acc(params)
    state = init_opt_state(oc, params)
    p = params
    grad_fn = jax.jit(jax.grad(lambda p, b: MN.loss_fn(cfg, (None, p), b)[0]))
    upd = jax.jit(lambda p, g, s, t: apply_updates(oc, p, g, s, t))
    for step in range(30):
        b = task.batch(32, step, "target")
        g = grad_fn(p, {"images": jnp.asarray(b["images"]),
                        "labels": jnp.asarray(b["labels"])})
        p, state = upd(p, g, state, step)
    acc_full = eval_acc(p)
    assert acc_full > acc0 + 0.2, (acc0, acc_full)


def test_dynamic_phase_changes_selection_every_step():
    from repro.core import build_plan, random_selection
    from repro.core.schedule import maybe_reselect
    cfg = get_smoke_config("llama3-8b")
    sp = SparseUpdateConfig(update_ratio=0.3, num_update_layers=2,
                            channel_block=8, phase_fixed_early=0,
                            phase_dynamic=100)
    plan = build_plan(cfg, sp)
    idx = random_selection(plan, jax.random.PRNGKey(0))
    seen = set()
    for step in range(5):
        key = jax.random.fold_in(jax.random.PRNGKey(1), step)
        idx = maybe_reselect(plan, sp, idx, jnp.asarray(step), key)
        # hash the FULL selection state (single small leaves can collide)
        seen.add(b"".join(np.asarray(l).tobytes()
                          for l in jax.tree.leaves(idx)))
    assert len(seen) == 5, "dynamic phase must re-randomize every step"


def test_split_tree_grad_memory():
    """Gradient buffers exist only for the trainable suffix (split-tree
    autodiff): trainable tree is a small fraction of the params."""
    from repro.train import make_train_state
    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("t", 16, 2, "train")
    tc = TrainConfig(model=cfg, shape=shape,
                     sparse=SparseUpdateConfig(update_ratio=0.2,
                                               num_update_layers=1,
                                               channel_block=8),
                     optimizer=OptimizerConfig(kind="sgd"))
    state, plan = make_train_state(tc, jax.random.PRNGKey(0))
    n_train = sum(x.size for x in jax.tree.leaves(state["params_trainable"]))
    n_frozen = sum(x.size for x in jax.tree.leaves(state["params_frozen"]))
    assert n_train * 2 < n_frozen


def test_merge_params_reconstructs_full_model():
    from repro.train import make_train_state
    from repro.train.steps import merge_params
    from repro.models import transformer as T
    cfg = get_smoke_config("llama3-8b")
    shape = ShapeConfig("t", 16, 2, "train")
    tc = TrainConfig(model=cfg, shape=shape,
                     sparse=SparseUpdateConfig(update_ratio=0.5,
                                               num_update_layers=1,
                                               channel_block=8),
                     optimizer=OptimizerConfig(kind="sgd"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    state, plan = make_train_state(tc, key, params=params)
    merged = merge_params(state["params_frozen"], state["params_trainable"])
    for (pa, a), (pb, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(params),
                   key=lambda t: str(t[0])),
            sorted(jax.tree_util.tree_leaves_with_path(merged),
                   key=lambda t: str(t[0]))):
        assert str(pa) == str(pb)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
