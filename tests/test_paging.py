"""Property-based tests for the page allocator and prefix cache.

Model-based: a python-dict reference tracks who holds references to which
page; after arbitrary op sequences the pool must agree with the model,
never double-free, never leak (releasing every reference returns the pool
to fully-free). Runs under hypothesis when installed, and under the
seeded-random fallback in `repro.testing` otherwise — either way the
invariants are exercised, not skipped.
"""
import numpy as np
import pytest

from repro.testing import given, settings, st
from repro.serve.paging import PagePool, PrefixCache

PS = 4


# ---------------------------------------------------------------------------
# PagePool: alloc/free/incref/decref/cow_split never double-free, never leak
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2 ** 20)),
                 min_size=1, max_size=120),
    num_pages=st.integers(1, 8),
)
def test_page_pool_model(ops, num_pages):
    pool = PagePool(num_pages, PS)
    held = []                       # references we own, with multiplicity
    for op, arg in ops:
        if op == 0 and pool.free_pages:                    # alloc
            held.append(pool.alloc())
        elif op == 1 and held:                             # incref
            pid = held[arg % len(held)]
            pool.incref(pid)
            held.append(pid)
        elif op == 2 and held:                             # decref
            pool.decref(held.pop(arg % len(held)))
        elif op == 3:                                      # cow_split
            shared = sorted({p for p in held if pool.ref[p] >= 2})
            if shared and pool.free_pages:
                pid = shared[arg % len(shared)]
                held.remove(pid)
                held.append(pool.cow_split(pid))
        pool.check()
        assert pool.in_use == len(set(held))
        for pid in set(held):
            assert pool.ref[pid] == held.count(pid)
    for pid in list(held):          # release everything: no page may leak
        pool.decref(pid)
    pool.check()
    assert pool.free_pages == num_pages


def test_page_pool_double_free_raises():
    pool = PagePool(2, PS)
    pid = pool.alloc()
    pool.decref(pid)
    with pytest.raises(RuntimeError, match="double-free"):
        pool.decref(pid)
    pool.check()


def test_cow_split_semantics():
    pool = PagePool(3, PS)
    pid = pool.alloc()
    pool.incref(pid)                # shared between two holders
    new = pool.cow_split(pid)
    assert new != pid
    assert pool.ref[pid] == 1 and pool.ref[new] == 1
    assert pool.cow_splits == 1
    pool.decref(pid)
    pool.decref(new)
    pool.check()
    assert pool.free_pages == 3


def test_alloc_exhausted_raises():
    pool = PagePool(1, PS)
    pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()


# ---------------------------------------------------------------------------
# PrefixCache: chain-hash matching returns the right pages, eviction frees
# exactly the unpinned ones, and the whole thing releases cleanly
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    n_reqs=st.integers(1, 8),
    vocab=st.sampled_from([2, 3, 50]),      # tiny vocab: forced collisions
)
def test_prefix_cache_model(seed, n_reqs, vocab):
    rng = np.random.default_rng(seed)
    pool = PagePool(64, PS)
    cache = PrefixCache(pool)
    content = {}                    # pid -> token bytes it must represent

    for _ in range(n_reqs):
        plen = int(rng.integers(1, 4 * PS))
        toks = rng.integers(0, vocab, plen).astype(np.int32)
        pages, covered = cache.match(toks, plen - 1)
        assert covered <= plen - 1
        # every matched page must hold exactly the claimed prompt slice
        off = 0
        for pid, fill in pages:
            assert content[pid][:fill * 4] == \
                np.ascontiguousarray(toks[off:off + fill]).tobytes()[:fill * 4]
            off += fill
        held = [pid for pid, _ in pages]
        n_full_matched = sum(1 for _, f in pages if f == PS)
        if pages and pages[-1][1] < PS:
            # appending to a shared partial page requires a COW split first
            # (the engine copies the device rows; here we copy the content)
            if pool.free_pages:
                new = pool.cow_split(pages[-1][0])
                lo = (len(held) - 1) * PS
                content[new] = np.ascontiguousarray(
                    toks[lo:lo + PS]).tobytes()
                held[-1] = new
            else:
                pool.decref(held.pop())
        # "prefill" the rest: allocate the remaining pages this prompt needs
        n_pages = -(-plen // PS)
        while len(held) < n_pages and pool.free_pages:
            pid = pool.alloc()
            lo = len(held) * PS
            content[pid] = np.ascontiguousarray(
                toks[lo:lo + PS]).tobytes()
            held.append(pid)
        if len(held) == n_pages:
            reg = cache.register_full(toks, plen // PS, held, n_full_matched)
            assert reg == plen // PS
            if plen % PS and rng.random() < 0.7:
                cache.register_partial(toks, held[-1])
        pool.check()
        for pid in held:            # request finishes
            pool.decref(pid)
        pool.check()

    while cache.evict_one():        # drain the cache: nothing may leak
        pool.check()
    assert len(cache) == 0 or all(
        pool.ref[e if isinstance(e, int) else e[0]] > 1
        for t in (cache._full, cache._partial) for e in t.values())
    assert pool.free_pages == pool.num_pages


def test_prefix_cache_eviction_respects_pins():
    pool = PagePool(4, PS)
    cache = PrefixCache(pool)
    toks = np.arange(2 * PS, dtype=np.int32)
    a, b = pool.alloc(), pool.alloc()
    cache.register_full(toks, 2, [a, b], 0)
    pool.decref(a)                  # request done: only cache holds a
    # b still held by "the request": eviction must free a but never b
    assert cache.evict_one()
    assert pool.ref[a] == 0 and pool.ref[b] == 2
    assert not cache.evict_one()    # b is pinned
    pool.decref(b)
    assert cache.evict_one()
    pool.check()
    assert pool.free_pages == 4


def test_exact_multiple_registers_no_partial():
    """fill == 0 edge: a prompt whose length is an exact page multiple has
    no partially-filled last page — register_partial must refuse, take no
    pool reference, and leave the partial table empty."""
    pool = PagePool(4, PS)
    cache = PrefixCache(pool)
    toks = np.arange(2 * PS, dtype=np.int32)
    pids = [pool.alloc(), pool.alloc()]
    cache.register_full(toks, 2, pids, 0)
    refs_before = pool.ref.copy()
    assert cache.register_partial(toks, pids[-1]) is False
    assert (pool.ref == refs_before).all()
    assert len(cache._partial) == 0
    for pid in pids:
        pool.decref(pid)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == 4


def test_exact_multiple_match_downgrades_last_full_page():
    """fill == 0 edge, match side: an identical exact-multiple prompt must
    reuse the registrant's LAST full page as a ps-1 partial match (the
    >= 1-uncached-token cap blocks a full match), while a prompt whose last
    page differs must not."""
    pool = PagePool(6, PS)
    cache = PrefixCache(pool)
    toks = np.asarray(range(2 * PS), np.int32)
    pids = [pool.alloc(), pool.alloc()]
    cache.register_full(toks, 2, pids, 0)

    pages, covered = cache.match(toks, len(toks) - 1)
    assert covered == 2 * PS - 1
    assert [f for _, f in pages] == [PS, PS - 1]
    assert pages[-1][0] == pids[-1]
    assert pool.ref[pids[-1]] == 3          # holder + cache + this match
    cache.abandon(pages, len(toks))

    # the downgrade is hash-gated on the full last page's content
    diff = toks.copy()
    diff[-1] += 1
    pages, covered = cache.match(diff, len(diff) - 1)
    assert covered == PS and [f for _, f in pages] == [PS]
    for pid, _ in pages:
        pool.decref(pid)

    # a LONGER prompt sharing the pages must still full-match both (the
    # downgrade only fires when the cap — not a miss — stopped the loop)
    longer = np.concatenate([toks, np.asarray([7, 8], np.int32)])
    pages, covered = cache.match(longer, len(longer) - 1)
    assert covered == 2 * PS and [f for _, f in pages] == [PS, PS]
    for pid, _ in pages:
        pool.decref(pid)
    for pid in pids:
        pool.decref(pid)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == 6


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    n_pages_len=st.integers(1, 3),
)
def test_exact_multiple_roundtrip_property(seed, n_pages_len):
    """Register/match round trip pinned AT the exact-multiple lengths:
    matched pages always hold exactly the claimed token content, refcounts
    balance, and draining the cache frees every page."""
    rng = np.random.default_rng(seed)
    pool = PagePool(32, PS)
    cache = PrefixCache(pool)
    content = {}
    toks = rng.integers(0, 3, n_pages_len * PS).astype(np.int32)
    for attempt in range(3):                 # same prompt resubmitted
        pages, covered = cache.match(toks, len(toks) - 1)
        assert covered <= len(toks) - 1
        off = 0
        for pid, fill in pages:
            assert content[pid][:fill * 4] == np.ascontiguousarray(
                toks[off:off + fill]).tobytes()[:fill * 4]
            off += fill
        held = [pid for pid, _ in pages]
        n_full = sum(1 for _, f in pages if f == PS)
        if pages and pages[-1][1] < PS:      # write boundary: COW first
            new = pool.cow_split(pages[-1][0])
            content[new] = content[held[-1]]
            held[-1] = new
        while len(held) < n_pages_len:
            pid = pool.alloc()
            lo = len(held) * PS
            content[pid] = np.ascontiguousarray(toks[lo:lo + PS]).tobytes()
            held.append(pid)
        reg = cache.register_full(toks, n_pages_len, held, n_full)
        assert reg == n_pages_len
        assert cache.register_partial(toks, held[-1]) is False   # fill == 0
        pool.check()
        if attempt > 0:                      # resubmits must hit the cache
            assert covered > 0
        for pid in held:
            pool.decref(pid)
        pool.check()
    while cache.evict_one():
        pool.check()
    assert pool.free_pages == pool.num_pages


def test_prefix_match_is_content_checked():
    """A partial-page entry only matches identical token content."""
    pool = PagePool(4, PS)
    cache = PrefixCache(pool)
    toks = np.asarray([1, 2, 3, 4, 5, 6], np.int32)     # 1 full + 2 partial
    pids = [pool.alloc(), pool.alloc()]
    cache.register_full(toks, 1, pids, 0)
    cache.register_partial(toks, pids[1])
    same = np.asarray([1, 2, 3, 4, 5, 6, 9], np.int32)
    pages, covered = cache.match(same, len(same) - 1)
    assert covered == 6 and [f for _, f in pages] == [PS, 2]
    for pid, _ in pages:
        pool.decref(pid)
    diff = np.asarray([1, 2, 3, 4, 5, 7, 9], np.int32)  # partial differs
    pages, covered = cache.match(diff, len(diff) - 1)
    assert covered == PS and [f for _, f in pages] == [PS]
    for pid, _ in pages:
        pool.decref(pid)
    for pid in pids:
        pool.decref(pid)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == 4
