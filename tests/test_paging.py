"""Property-based tests for the page allocator and the prefix caches.

Model-based: a python-dict reference tracks who holds references to which
page; after arbitrary op sequences the pool must agree with the model,
never double-free, never leak (releasing every reference returns the pool
to fully-free). The radix tree is additionally checked against a
brute-force longest-common-prefix reference and its own structural audit
(`check()`): refcount conservation, no page leaks, pinned nodes never
evicted, spill -> rehydrate byte-identical. Runs under hypothesis when
installed, and under the seeded-random fallback in `repro.testing`
otherwise — either way the invariants are exercised, not skipped.
"""
import numpy as np
import pytest

from repro.testing import given, settings, st
from repro.serve.paging import (ChainPrefixCache, PagePool, RadixPrefixCache,
                                SpillTier)

PS = 4


# ---------------------------------------------------------------------------
# PagePool: alloc/free/incref/decref/cow_split never double-free, never leak
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2 ** 20)),
                 min_size=1, max_size=120),
    num_pages=st.integers(1, 8),
)
def test_page_pool_model(ops, num_pages):
    pool = PagePool(num_pages, PS)
    held = []                       # references we own, with multiplicity
    for op, arg in ops:
        if op == 0 and pool.free_pages:                    # alloc
            held.append(pool.alloc())
        elif op == 1 and held:                             # incref
            pid = held[arg % len(held)]
            pool.incref(pid)
            held.append(pid)
        elif op == 2 and held:                             # decref
            pool.decref(held.pop(arg % len(held)))
        elif op == 3:                                      # cow_split
            shared = sorted({p for p in held if pool.ref[p] >= 2})
            if shared and pool.free_pages:
                pid = shared[arg % len(shared)]
                held.remove(pid)
                held.append(pool.cow_split(pid))
        pool.check()
        assert pool.in_use == len(set(held))
        for pid in set(held):
            assert pool.ref[pid] == held.count(pid)
    for pid in list(held):          # release everything: no page may leak
        pool.decref(pid)
    pool.check()
    assert pool.free_pages == num_pages


def test_page_pool_double_free_raises():
    pool = PagePool(2, PS)
    pid = pool.alloc()
    pool.decref(pid)
    with pytest.raises(RuntimeError, match="double-free"):
        pool.decref(pid)
    pool.check()


def test_cow_split_semantics():
    pool = PagePool(3, PS)
    pid = pool.alloc()
    pool.incref(pid)                # shared between two holders
    new = pool.cow_split(pid)
    assert new != pid
    assert pool.ref[pid] == 1 and pool.ref[new] == 1
    assert pool.cow_splits == 1
    pool.decref(pid)
    pool.decref(new)
    pool.check()
    assert pool.free_pages == 3


def test_alloc_exhausted_raises():
    pool = PagePool(1, PS)
    pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()


# ---------------------------------------------------------------------------
# RadixPrefixCache: matching agrees with a brute-force LCP reference, pages
# always hold the claimed content, eviction respects pins and refcounts, and
# spill -> rehydrate is byte-identical
# ---------------------------------------------------------------------------

class _FakeDevice:
    """Stand-in for the engine's layer pools: one host array of token rows,
    written by 'prefill' and moved through the spill reader/writer."""

    def __init__(self, num_pages):
        self.rows = np.zeros((num_pages * PS, 3), np.float32)

    def reader(self, pid):
        return {"rows": self.rows[pid * PS:(pid + 1) * PS].copy()}

    def writer(self, pid, blob):
        self.rows[pid * PS:(pid + 1) * PS] = blob["rows"]

    def fill(self, pid, toks):
        """Page content derived from token content — makes 'does this page
        hold the right rows' checkable after any sharing/spill shuffle."""
        self.rows[pid * PS:(pid + 1) * PS] = \
            np.asarray(toks, np.float32)[:, None]


def _mk_radix(num_pages=64, spill=None, **kw):
    pool = PagePool(num_pages, PS)
    dev = _FakeDevice(num_pages)
    cache = RadixPrefixCache(pool, has_pages=True, reader=dev.reader,
                             writer=dev.writer, spill=spill, **kw)
    return pool, dev, cache


def _submit(pool, dev, cache, toks, content):
    """Drive one request through the engine's cache protocol: match,
    COW the partial continuation, 'prefill' the uncovered pages, insert.
    Returns the pages the request held (already released)."""
    plen = len(toks)
    mr = cache.match(toks, plen - 1)
    assert mr.covered <= plen - 1
    off = 0
    for pid, fill in mr.pages:      # matched content must be exact
        assert content[pid][:fill * 4] == \
            np.ascontiguousarray(toks[off:off + fill]).tobytes()[:fill * 4]
        off += fill
    held = [pid for pid, _ in mr.pages]
    n_full = sum(1 for _, f in mr.pages if f == PS)
    if mr.pages and mr.pages[-1][1] < PS:
        if pool.free_pages:         # append => COW the shared page first
            new = pool.cow_split(mr.pages[-1][0])
            lo = (len(held) - 1) * PS
            dev.fill(new, np.resize(toks[lo:], PS))
            content[new] = np.ascontiguousarray(toks[lo:lo + PS]).tobytes()
            held[-1] = new
        else:
            pool.decref(held.pop())
    n_pages = -(-plen // PS)
    while len(held) < n_pages and pool.free_pages:
        pid = pool.alloc()
        lo = len(held) * PS
        dev.fill(pid, np.resize(toks[lo:], PS))
        content[pid] = np.ascontiguousarray(toks[lo:lo + PS]).tobytes()
        held.append(pid)
    if len(held) == n_pages:
        reg = cache.insert_pages(toks, plen // PS, held, n_full)
        assert reg == plen // PS
        if plen % PS:
            cache.insert_partial(toks, held[-1])
    cache.release(mr)
    pool.check()
    cache.check()
    for pid in held:                # request finishes
        pool.decref(pid)
    return held


def _brute_force_shared_pages(toks, registered):
    """Reference: full pages of `toks` any fully-registered prompt shares."""
    best = 0
    for r in registered:
        n = 0
        lim = min(len(toks), len(r)) // PS
        while n < lim and np.array_equal(toks[n * PS:(n + 1) * PS],
                                         r[n * PS:(n + 1) * PS]):
            n += 1
        best = max(best, min(n, len(r) // PS))
    return best


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    n_reqs=st.integers(1, 10),
    vocab=st.sampled_from([2, 3, 50]),      # tiny vocab: forced collisions
)
def test_radix_match_vs_brute_force_lcp(seed, n_reqs, vocab):
    rng = np.random.default_rng(seed)
    pool, dev, cache = _mk_radix()
    content = {}
    registered = []                 # prompts whose full pages all landed
    for _ in range(n_reqs):
        plen = int(rng.integers(1, 5 * PS))
        toks = rng.integers(0, vocab, plen).astype(np.int32)
        cap = plen - 1
        mr = cache.match(toks, cap)
        n_full = sum(1 for _, f in mr.pages if f == PS)
        # the tree must find every full page a registered prompt shares
        # (up to the >=1-uncached-token cap) — the radix guarantee the
        # whole-chain design could only give for whole registered chains
        assert n_full >= min(_brute_force_shared_pages(toks, registered),
                             cap // PS)
        cache.abandon(mr, plen)
        held = _submit(pool, dev, cache, toks, content)
        if len(held) == -(-plen // PS):
            registered.append(toks)
    while cache.evict_one():        # drain: nothing may leak
        pool.check()
        cache.check()
    assert cache.node_count == 0
    assert pool.free_pages == pool.num_pages


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    n_ops=st.integers(5, 30),
)
def test_radix_invariants_under_random_ops(seed, n_ops):
    """Random submit/match/evict/spill/rehydrate interleavings: pool and
    tree audits hold after every op, and a full drain frees every page."""
    rng = np.random.default_rng(seed)
    spill = SpillTier(32)
    pool, dev, cache = _mk_radix(num_pages=16, spill=spill)
    content = {}
    pinned = []                     # live matches (simulated open slots)
    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0:                 # submit a request end-to-end
            plen = int(rng.integers(1, 4 * PS))
            toks = rng.integers(0, 3, plen).astype(np.int32)
            if pool.free_pages >= -(-plen // PS) + 1:
                _submit(pool, dev, cache, toks, content)
        elif op == 1 and cache.node_count:      # evict one leaf
            cache.evict_one()
        elif op == 2:               # match and HOLD the pin (open slot)
            plen = int(rng.integers(2, 4 * PS))
            toks = rng.integers(0, 3, plen).astype(np.int32)
            mr = cache.match(toks, plen - 1)
            pinned.append((mr, plen))
        else:                       # close an open slot
            if pinned:
                mr, plen = pinned.pop(int(rng.integers(0, len(pinned))))
                for pid, _ in mr.pages:
                    pool.decref(pid)
                cache.release(mr)
        pool.check()
        cache.check()
    for mr, _ in pinned:
        for pid, _ in mr.pages:
            pool.decref(pid)
        cache.release(mr)
    while cache.evict_one():
        pool.check()
        cache.check()
    assert cache.node_count == 0
    assert pool.free_pages == pool.num_pages


def test_radix_pinned_never_evicted():
    pool, dev, cache = _mk_radix(num_pages=8)
    toks = np.arange(2 * PS, dtype=np.int32)
    content = {}
    _submit(pool, dev, cache, toks, content)
    mr = cache.match(toks, 2 * PS - 1)          # pins the deepest node
    # matched pages are referenced by the match => nothing evictable
    assert cache.evictable() == 0
    assert not cache.evict_one()
    for pid, _ in mr.pages:
        pool.decref(pid)
    # pages released but the PIN alone must still protect the node
    assert not cache.evict_one()
    cache.release(mr)
    assert cache.evict_one()
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == pool.num_pages


def test_radix_eviction_is_lru_leaf_first():
    """Two sibling branches: the least-recently-touched leaf goes first,
    and evicting a leaf makes its parent evictable next."""
    pool, dev, cache = _mk_radix(num_pages=16)
    content = {}
    shared = np.arange(PS, dtype=np.int32)
    a = np.concatenate([shared, np.full(PS, 90, np.int32)])
    b = np.concatenate([shared, np.full(PS, 91, np.int32)])
    _submit(pool, dev, cache, a, content)
    _submit(pool, dev, cache, b, content)       # splits: shared + 2 leaves
    assert cache.node_count == 3
    # touch branch a AFTER b: b's leaf is now the LRU leaf
    cache.abandon(cache.match(a, 2 * PS - 1), 2 * PS)
    free0 = pool.free_pages
    assert cache.evict_one()
    assert pool.free_pages == free0 + 1
    # branch a must still fully match; b's tail must be gone
    mr = cache.match(a, 2 * PS - 1)
    assert sum(f for _, f in mr.pages) >= 2 * PS - 1
    cache.abandon(mr, 2 * PS)
    mr = cache.match(b, 2 * PS - 1)
    assert mr.covered == PS                     # only the shared page left
    cache.abandon(mr, 2 * PS)
    while cache.evict_one():
        pass
    cache.check()
    pool.check()
    assert pool.free_pages == pool.num_pages


def test_spill_rehydrate_roundtrip_byte_identical():
    """Evicting a node writes its device rows (and snapshot) to the host
    tier; a later match re-attaches them bit-for-bit."""
    spill = SpillTier(16)
    pool, dev, cache = _mk_radix(num_pages=8, spill=spill)
    content = {}
    toks = np.arange(3 * PS, dtype=np.int32)
    held = _submit(pool, dev, cache, toks, content)
    snap = {"s": np.arange(5, dtype=np.float32), "last": np.ones(2)}
    assert cache.insert_snapshot(toks, 2 * PS, {k: v.copy()
                                                for k, v in snap.items()})
    want_rows = [dev.rows[pid * PS:(pid + 1) * PS].copy()
                 for pid in held[:2]]
    while cache.evict_one():
        pass
    assert cache.node_count == 0 and pool.free_pages == pool.num_pages
    assert len(spill) == 3 and cache.spills >= 3
    dev.rows[:] = -1                            # scramble the device pools
    mr = cache.match(toks, 3 * PS - 1, need_state=True)
    assert cache.rehydrates == 2
    assert mr.covered == 2 * PS and mr.snapshot is not None
    for k in snap:
        assert np.array_equal(mr.snapshot[k], snap[k])
    got = np.concatenate([dev.rows[pid * PS:(pid + 1) * PS]
                          for pid, _ in mr.pages])
    assert np.array_equal(got, np.concatenate(want_rows))
    cache.abandon(mr, 3 * PS)
    cache.check()
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == pool.num_pages


def test_spill_tier_writeback_queue_bound():
    """The tier is an O(1) LRU writeback queue: overflowing drops the
    least-recently-written entry, re-putting refreshes recency."""
    tier = SpillTier(max_entries=3)
    for i in range(3):
        tier.put(np.asarray([i], np.int32), snap={"x": np.asarray([i])})
    tier.put(np.asarray([0], np.int32), snap={"x": np.asarray([10])})
    tier.put(np.asarray([3], np.int32), snap={"x": np.asarray([3])})
    assert len(tier) == 3 and tier.evicted == 1
    assert tier.peek(np.asarray([1], np.int32)) is None     # LRU dropped
    assert tier.peek(np.asarray([0], np.int32))["snap"]["x"][0] == 10
    assert [int(t[0]) for t, _ in tier.items()] == [2, 0, 3]


def test_stateless_snapshot_cache():
    """Page-less archs (rwkv): nodes carry snapshots only, need_state
    matching clamps to the deepest snapshot boundary, and the snapshot
    budget spills the oldest blob to the tier."""
    pool = PagePool(1, PS)
    spill = SpillTier(8)
    cache = RadixPrefixCache(pool, has_pages=False, spill=spill,
                             snapshot_budget=2)
    toks = np.arange(4 * PS, dtype=np.int32)
    assert cache.wants_snapshot(toks, PS)
    assert not cache.wants_snapshot(toks, PS + 1)       # not page-aligned
    cache.insert_snapshot(toks, PS, {"s": np.full(3, 1.0)})
    assert not cache.wants_snapshot(toks, PS)           # first write wins
    cache.insert_snapshot(toks, 3 * PS, {"s": np.full(3, 3.0)})
    mr = cache.match(toks, 4 * PS - 1, need_state=True)
    assert mr.covered == 3 * PS and mr.snapshot["s"][0] == 3.0
    assert not mr.pages                                  # nothing paged
    cache.release(mr)
    # a diverging prompt only reaches the shallower snapshot
    div = toks.copy()
    div[2 * PS] += 1
    mr = cache.match(div, 4 * PS - 1, need_state=True)
    assert mr.covered == PS and mr.snapshot["s"][0] == 1.0
    cache.abandon(mr, len(div))
    # budget = 2: a third snapshot spills the LRU blob to the host tier
    cache.insert_snapshot(toks, 2 * PS, {"s": np.full(3, 2.0)})
    assert len(cache._snaps) == 2 and cache.spills == 1 and len(spill) == 1
    cache.check()
    while cache.evict_one():
        pass
    assert cache.node_count == 0
    pool.check()


def test_partial_continuations_coexist_only_in_radix():
    """Content-distinct partial continuations of the same full-page spine:
    the radix tree keeps both, the chain baseline's one-slot-per-chain
    design keeps only the first — a strict radix win."""
    base = np.arange(PS, dtype=np.int32)
    p1 = np.concatenate([base, np.asarray([50, 51], np.int32)])
    p2 = np.concatenate([base, np.asarray([60, 61], np.int32)])

    pool, dev, cache = _mk_radix(num_pages=8)
    content = {}
    _submit(pool, dev, cache, p1, content)
    _submit(pool, dev, cache, p2, content)
    for q in (p1, p2):
        mr = cache.match(np.append(q, 7).astype(np.int32), len(q))
        assert mr.covered == len(q), q          # full page + its partial
        cache.abandon(mr, len(q) + 1)

    chain_pool = PagePool(8, PS)
    chain = ChainPrefixCache(chain_pool)
    pids = [chain_pool.alloc() for _ in range(3)]
    chain.insert_pages(p1, 1, pids[:1], 0)
    chain.insert_partial(p1, pids[1])
    assert chain.insert_partial(p2, pids[2]) is False   # slot taken
    mr = chain.match(np.append(p2, 7).astype(np.int32), len(p2))
    assert mr.covered == PS                     # partial p2 NOT matched
    chain.abandon(mr, len(p2) + 1)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == pool.num_pages


def test_partial_slots_lru_bounded():
    """At most `partial_slots` continuations per spine are retained,
    LRU-displaced beyond that — the tree must not hoard one speculative
    page per historical request (that would push peak page usage ABOVE
    the no-sharing run's)."""
    pool, dev, cache = _mk_radix(num_pages=16)
    content = {}
    base = np.arange(PS, dtype=np.int32)
    tails = [np.concatenate([base, np.asarray([t, t + 1], np.int32)])
             for t in (50, 60, 70)]
    for t in tails:
        _submit(pool, dev, cache, t, content)
    assert cache.node_count == 3            # spine + partial_slots leaves
    # the oldest partial was displaced: its prompt only matches the spine
    mr = cache.match(np.append(tails[0], 7).astype(np.int32), len(tails[0]))
    assert mr.covered == PS
    cache.abandon(mr, len(tails[0]) + 1)
    for t in tails[1:]:                     # the newer two still hit fully
        mr = cache.match(np.append(t, 7).astype(np.int32), len(t))
        assert mr.covered == len(t), t
        cache.abandon(mr, len(t) + 1)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == pool.num_pages


def test_exact_multiple_match_downgrades_last_full_page():
    """fill == 0 edge, match side: an identical exact-multiple prompt must
    reuse the registrant's LAST full page as a ps-1 partial match (the
    >= 1-uncached-token cap blocks a full match), while a prompt whose last
    page differs must not. Checked for BOTH cache implementations."""
    for make in (lambda p: _mk_radix(num_pages=6)[2],
                 ChainPrefixCache):
        pool = PagePool(6, PS)
        cache = make(pool) if make is ChainPrefixCache else None
        if cache is None:
            pool, dev, cache = _mk_radix(num_pages=6)
        toks = np.asarray(range(2 * PS), np.int32)
        pids = [pool.alloc(), pool.alloc()]
        cache.insert_pages(toks, 2, pids, 0)

        mr = cache.match(toks, len(toks) - 1)
        assert mr.covered == 2 * PS - 1
        assert [f for _, f in mr.pages] == [PS, PS - 1]
        assert mr.pages[-1][0] == pids[-1]
        assert pool.ref[pids[-1]] == 3      # holder + cache + this match
        cache.abandon(mr, len(toks))

        # the downgrade is content-gated on the full last page
        diff = toks.copy()
        diff[-1] += 1
        mr = cache.match(diff, len(diff) - 1)
        assert mr.covered == PS and [f for _, f in mr.pages] == [PS]
        for pid, _ in mr.pages:
            pool.decref(pid)
        cache.release(mr)

        # a LONGER prompt sharing the pages must still full-match both (the
        # downgrade only fires when the cap — not a miss — stopped the loop)
        longer = np.concatenate([toks, np.asarray([7, 8], np.int32)])
        mr = cache.match(longer, len(longer) - 1)
        assert mr.covered == 2 * PS and [f for _, f in mr.pages] == [PS, PS]
        for pid, _ in mr.pages:
            pool.decref(pid)
        cache.release(mr)
        for pid in pids:
            pool.decref(pid)
        while cache.evict_one():
            pass
        pool.check()
        assert pool.free_pages == 6


def test_exact_multiple_registers_no_partial():
    """fill == 0 edge: a prompt whose length is an exact page multiple has
    no partially-filled last page — insert_partial must refuse, take no
    pool reference, and add no node."""
    pool, dev, cache = _mk_radix(num_pages=4)
    toks = np.arange(2 * PS, dtype=np.int32)
    pids = [pool.alloc(), pool.alloc()]
    cache.insert_pages(toks, 2, pids, 0)
    refs_before = pool.ref.copy()
    nodes_before = cache.node_count
    assert cache.insert_partial(toks, pids[-1]) is False
    assert (pool.ref == refs_before).all()
    assert cache.node_count == nodes_before
    for pid in pids:
        pool.decref(pid)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == 4


def test_prefix_match_is_content_checked():
    """A partial-page entry only matches identical token content."""
    pool, dev, cache = _mk_radix(num_pages=4)
    toks = np.asarray([1, 2, 3, 4, 5, 6], np.int32)     # 1 full + 2 partial
    pids = [pool.alloc(), pool.alloc()]
    cache.insert_pages(toks, 1, pids, 0)
    cache.insert_partial(toks, pids[1])
    same = np.asarray([1, 2, 3, 4, 5, 6, 9], np.int32)
    mr = cache.match(same, len(same) - 1)
    assert mr.covered == 6 and [f for _, f in mr.pages] == [PS, 2]
    for pid, _ in mr.pages:
        pool.decref(pid)
    cache.release(mr)
    diff = np.asarray([1, 2, 3, 4, 5, 7, 9], np.int32)  # partial differs
    mr = cache.match(diff, len(diff) - 1)
    assert mr.covered == PS and [f for _, f in mr.pages] == [PS]
    for pid, _ in mr.pages:
        pool.decref(pid)
    cache.release(mr)
    for pid in pids:
        pool.decref(pid)
    while cache.evict_one():
        pass
    pool.check()
    assert pool.free_pages == 4


# ---------------------------------------------------------------------------
# ChainPrefixCache baseline keeps its original model-based coverage under the
# unified interface
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    n_reqs=st.integers(1, 8),
    vocab=st.sampled_from([2, 3, 50]),
)
def test_chain_prefix_cache_model(seed, n_reqs, vocab):
    rng = np.random.default_rng(seed)
    pool = PagePool(64, PS)
    cache = ChainPrefixCache(pool)
    content = {}
    for _ in range(n_reqs):
        plen = int(rng.integers(1, 4 * PS))
        toks = rng.integers(0, vocab, plen).astype(np.int32)
        mr = cache.match(toks, plen - 1)
        assert mr.covered <= plen - 1
        off = 0
        for pid, fill in mr.pages:
            assert content[pid][:fill * 4] == np.ascontiguousarray(
                toks[off:off + fill]).tobytes()[:fill * 4]
            off += fill
        held = [pid for pid, _ in mr.pages]
        n_full = sum(1 for _, f in mr.pages if f == PS)
        if mr.pages and mr.pages[-1][1] < PS:
            if pool.free_pages:
                new = pool.cow_split(mr.pages[-1][0])
                lo = (len(held) - 1) * PS
                content[new] = np.ascontiguousarray(
                    toks[lo:lo + PS]).tobytes()
                held[-1] = new
            else:
                pool.decref(held.pop())
        n_pages = -(-plen // PS)
        while len(held) < n_pages and pool.free_pages:
            pid = pool.alloc()
            lo = len(held) * PS
            content[pid] = np.ascontiguousarray(toks[lo:lo + PS]).tobytes()
            held.append(pid)
        if len(held) == n_pages:
            reg = cache.insert_pages(toks, plen // PS, held, n_full)
            assert reg == plen // PS
            if plen % PS and rng.random() < 0.7:
                cache.insert_partial(toks, held[-1])
        pool.check()
        for pid in held:
            pool.decref(pid)
        pool.check()
    while cache.evict_one():
        pool.check()
    assert pool.free_pages == pool.num_pages
